//! Run checkpointing: persist and restore the full coordinator state
//! (global model, per-device lazy-aggregation state, counters, RNG
//! streams) so long table sweeps and the e2e training run survive
//! interruption.
//!
//! Format: a JSON header line (versioned, with dims for validation)
//! followed by raw little-endian `f32` sections, then — since version
//! 2 — one fixed-width RNG record per device plus one for the
//! coordinator coin. Version 1 checkpoints (no RNG section) still load,
//! with a warning: stochastic-quantizer algorithms (QSGD) resumed from
//! them will draw a fresh RNG stream and may diverge bitwise from the
//! uninterrupted run. Version **3** adds the global train-loss history
//! and per-device last-loss estimates to the header, so loss-driven
//! selection strategies (`loss-weighted`) resume on the same
//! information the uninterrupted run had; v1/v2 checkpoints still load
//! (with those histories empty). Version **4** adds the simulated
//! network accounting (cumulative `sim_time`, downlink bits, straggler
//! count) so time-to-accuracy curves continue correctly across a
//! resume; older versions load with those counters at zero. Version
//! **5** adds an optional nested `serve` header object — the
//! coordinator service's serve-state (expected client count, staged
//! device ids at snapshot time) — so a killed `--serve` process
//! restarted with `--resume` re-enters the same round with the same
//! client topology; checkpoints without it (all older versions, and
//! in-process runs) load with no serve-state. Version **6** makes the
//! per-device sections *sparse*: the header's `devices` count is the
//! total simulated population and a new `ids` array names the devices
//! whose state the snapshot actually tracks (the ones a virtualized
//! run ever materialized — see DESIGN.md §Population), so a 1M-device
//! run checkpoints O(touched), not O(population). v1–v5 checkpoints
//! (no `ids` key) still load, with every device tracked. Version **7**
//! adds an optional nested `async` header object plus a trailing
//! binary section — the buffered-async event engine's state
//! (DESIGN.md §Async): the simulated clock, in-flight upload events
//! with their arrival times and wire bytes, the partial commit buffer,
//! the dispatched-member pool, and the retained fold context — so a
//! buffered run resumes mid-buffer byte-identically. Clock and
//! arrival times live in the binary section as raw little-endian
//! `f64`, never as JSON text, so the resume is bit-exact by
//! construction. Sync runs and older checkpoints carry no `async`
//! section and load with it absent. Written atomically (temp file +
//! rename).

use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// One [`crate::util::rng::Xoshiro256pp`] stream state.
#[derive(Clone, Debug, PartialEq)]
pub struct RngState {
    /// The four xoshiro256++ state words.
    pub s: [u64; 4],
    /// Cached second Box–Muller output, if any.
    pub gauss_cache: Option<f64>,
}

/// Serializable snapshot of a run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Format version this snapshot was loaded from / will save as.
    pub version: u32,
    /// Next round index to execute.
    pub round: usize,
    /// Total simulated population `M` (v6+; equal to the tracked-device
    /// count when loaded from older versions).
    pub population: usize,
    /// Ids of the devices this snapshot tracks, ascending. May be a
    /// sparse subset of the population (v6+, virtualized runs); older
    /// versions load with every device tracked. The per-device sections
    /// (`device_q`, `device_stats`, `device_rng`, `device_last_loss`)
    /// are indexed positionally by this list.
    pub device_ids: Vec<usize>,
    /// Global model `θ`.
    pub theta: Vec<f32>,
    /// Previous-round model (for `‖θᵏ − θ^{k−1}‖²`).
    pub prev_theta: Vec<f32>,
    /// Server direction / running `q̄`.
    pub direction: Vec<f32>,
    /// Per-device stored reference vectors `q_m` (gathered space).
    pub device_q: Vec<Vec<f32>>,
    /// Per-device `(uploads, skips, prev_err_sq)`.
    pub device_stats: Vec<(u64, u64, f64)>,
    /// Per-device RNG streams (v2+; empty when loaded from v1).
    pub device_rng: Vec<RngState>,
    /// Coordinator coin RNG (MARINA sync coin; v2+).
    pub coin_rng: Option<RngState>,
    /// Model-difference history, most recent first.
    pub diff_history: Vec<f64>,
    /// Global train-loss history, most recent first (v3+; empty when
    /// loaded from older versions).
    pub loss_history: Vec<f64>,
    /// Per-device most recent local loss (v3+; NaN = never observed).
    pub device_last_loss: Vec<f64>,
    /// Cumulative uplink bits.
    pub cum_bits: u64,
    /// Cumulative downlink (broadcast) bits (v4+; 0 for older).
    pub bits_down: u64,
    /// Cumulative simulated wall-clock seconds (v4+; 0 for older).
    pub sim_time: f64,
    /// Cumulative straggler count (v4+; 0 for older).
    pub stragglers: u64,
    /// `f(θ⁰)` estimate (NaN before any participant-bearing round).
    pub init_loss: f64,
    /// `f(θ^{k−1})` estimate (NaN before any participant-bearing round).
    pub prev_loss: f64,
    /// Coordinator-service serve-state (v5+; `None` for in-process
    /// runs and older checkpoints).
    pub serve_state: Option<ServeState>,
    /// Buffered-async event-engine state (v7+; `None` for sync runs
    /// and older checkpoints).
    pub async_state: Option<AsyncState>,
}

/// One in-flight or buffered upload as checkpoint v7 serializes it.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncUpload {
    /// Originating device id.
    pub device: usize,
    /// Model version (commit count) the upload was computed against.
    pub version: usize,
    /// Absolute simulated arrival time; 0 for already-delivered
    /// uploads sitting in the commit buffer.
    pub arrival: f64,
    /// The validated wire bytes.
    pub bytes: Vec<u8>,
}

/// One dispatched cohort member awaiting its commit (checkpoint v7).
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncMember {
    /// Device id.
    pub device: usize,
    /// Model version the member trained against.
    pub version: usize,
    /// Local loss the member reported (`NaN` = never reported).
    pub loss: f64,
    /// Quantization level the member staged, if it uploaded one.
    pub level: Option<u8>,
    /// Whether the member staged an upload at dispatch.
    pub staged: bool,
}

/// Buffered-async engine state carried by v7 checkpoints: everything
/// the event loop needs to resume mid-buffer bit-exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncState {
    /// Next dispatch index (selection / fault / jitter stream key).
    pub next_dispatch: usize,
    /// Committed model versions so far.
    pub commits: usize,
    /// The simulated clock (≥ the cumulative `sim_time` mid-commit).
    pub clock: f64,
    /// Cohort size of the latest dispatch (admission estimate).
    pub last_cohort: usize,
    /// `RoundCtx::round` of the latest dispatch (all the context a
    /// server fold may read, with `fold_marina_sync`).
    pub fold_round: usize,
    /// `RoundCtx::marina_sync` of the latest dispatch.
    pub fold_marina_sync: bool,
    /// Uplink bits accumulated since the last commit.
    pub pending_bits_up: u64,
    /// Downlink bits accumulated since the last commit.
    pub pending_bits_down: u64,
    /// Stragglers accumulated since the last commit.
    pub pending_stragglers: u64,
    /// In-flight uploads, in the engine's queue order.
    pub events: Vec<AsyncUpload>,
    /// Arrived uploads awaiting the next commit (`arrival` = 0).
    pub buffer: Vec<AsyncUpload>,
    /// Dispatched members awaiting the next commit.
    pub pool: Vec<AsyncMember>,
}

/// Serve-state carried by checkpoints written from a
/// [`crate::protocol::CoordinatorService`] run: what a restarted
/// `--serve --resume` needs beyond the engine state itself.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeState {
    /// Client count the run was configured with; the device ranges a
    /// resumed coordinator assigns are a pure function of this, so
    /// rejoining clients land on their original ranges.
    pub clients: usize,
    /// Device ids whose results were staged in the round that
    /// completed just before the snapshot (forensic: snapshots are
    /// written at round boundaries, after the fold).
    pub staged: Vec<u32>,
}

/// Current format version.
pub const VERSION: u32 = 7;

/// Bytes of one serialized RNG record: 4×u64 state + present flag +
/// gauss flag + gauss f64.
const RNG_RECORD_BYTES: usize = 4 * 8 + 1 + 1 + 8;

impl Checkpoint {
    /// Write atomically to `path`. Saves as the current version when
    /// RNG streams are present (one per device), as version 1 otherwise
    /// (e.g. a re-saved v1 snapshot).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let with_rng = self.device_rng.len() == self.device_q.len();
        let version = if with_rng { VERSION } else { 1 };
        // Loss estimates may legitimately be NaN (snapshot before any
        // participant-bearing round); bare `NaN` is not JSON, so write
        // null and let `load` map it back to NaN.
        let loss = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let mut fields = vec![
            ("version", Json::Num(version as f64)),
            ("round", Json::Num(self.round as f64)),
            ("dim", Json::Num(self.theta.len() as f64)),
            // Since v6 `devices` is the total population; `ids` names
            // the tracked subset the binary sections cover.
            ("devices", Json::Num(self.population as f64)),
            (
                "ids",
                Json::Arr(
                    self.device_ids
                        .iter()
                        .map(|&i| Json::Num(i as f64))
                        .collect(),
                ),
            ),
            (
                "supports",
                Json::Arr(
                    self.device_q
                        .iter()
                        .map(|q| Json::Num(q.len() as f64))
                        .collect(),
                ),
            ),
            (
                "stats",
                Json::Arr(
                    self.device_stats
                        .iter()
                        .map(|&(u, s, e)| {
                            Json::Arr(vec![
                                Json::Num(u as f64),
                                Json::Num(s as f64),
                                Json::Num(e),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diff_history",
                Json::Arr(self.diff_history.iter().map(|&d| Json::Num(d)).collect()),
            ),
            (
                "loss_history",
                Json::Arr(self.loss_history.iter().map(|&l| loss(l)).collect()),
            ),
            (
                "device_last_loss",
                Json::Arr(self.device_last_loss.iter().map(|&l| loss(l)).collect()),
            ),
            ("cum_bits", Json::Num(self.cum_bits as f64)),
            ("bits_down", Json::Num(self.bits_down as f64)),
            ("sim_time", Json::Num(self.sim_time)),
            ("stragglers", Json::Num(self.stragglers as f64)),
            ("init_loss", loss(self.init_loss)),
            ("prev_loss", loss(self.prev_loss)),
        ];
        if let Some(ss) = &self.serve_state {
            let staged = ss.staged.iter().map(|&d| Json::Num(d as f64)).collect();
            fields.push((
                "serve",
                obj(vec![
                    ("clients", Json::Num(ss.clients as f64)),
                    ("staged", Json::Arr(staged)),
                ]),
            ));
        }
        // v7 buffered-async state: metadata in the header, clock /
        // arrival times / wire bytes in a trailing binary section (raw
        // little-endian, bit-exact). Only current-version snapshots
        // carry it — a v1 re-save has no reader for the extra bytes.
        let async_state = self.async_state.as_ref().filter(|_| with_rng);
        if let Some(a) = async_state {
            let upload_meta = |u: &AsyncUpload| {
                Json::Arr(vec![
                    Json::Num(u.device as f64),
                    Json::Num(u.version as f64),
                    Json::Num(u.bytes.len() as f64),
                ])
            };
            fields.push((
                "async",
                obj(vec![
                    ("next_dispatch", Json::Num(a.next_dispatch as f64)),
                    ("commits", Json::Num(a.commits as f64)),
                    ("last_cohort", Json::Num(a.last_cohort as f64)),
                    ("fold_round", Json::Num(a.fold_round as f64)),
                    (
                        "fold_sync",
                        Json::Num(if a.fold_marina_sync { 1.0 } else { 0.0 }),
                    ),
                    ("pending_up", Json::Num(a.pending_bits_up as f64)),
                    ("pending_down", Json::Num(a.pending_bits_down as f64)),
                    (
                        "pending_stragglers",
                        Json::Num(a.pending_stragglers as f64),
                    ),
                    ("events", Json::Arr(a.events.iter().map(upload_meta).collect())),
                    ("buffer", Json::Arr(a.buffer.iter().map(upload_meta).collect())),
                    (
                        "pool",
                        Json::Arr(
                            a.pool
                                .iter()
                                .map(|p| {
                                    Json::Arr(vec![
                                        Json::Num(p.device as f64),
                                        Json::Num(p.version as f64),
                                        loss(p.loss),
                                        Json::Num(
                                            p.level.map_or(-1.0, |l| l as f64),
                                        ),
                                        Json::Num(if p.staged { 1.0 } else { 0.0 }),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ));
        }
        let header = obj(fields);
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            writeln!(f, "{header}")?;
            write_f32s(&mut f, &self.theta)?;
            write_f32s(&mut f, &self.prev_theta)?;
            write_f32s(&mut f, &self.direction)?;
            for q in &self.device_q {
                write_f32s(&mut f, q)?;
            }
            if with_rng {
                for rng in &self.device_rng {
                    write_rng(&mut f, Some(rng))?;
                }
                write_rng(&mut f, self.coin_rng.as_ref())?;
            }
            // v7 async binary tail: clock, then each event's arrival +
            // wire bytes, then each buffered upload's wire bytes, in
            // header order.
            if let Some(a) = async_state {
                f.write_all(&a.clock.to_le_bytes())?;
                for u in &a.events {
                    f.write_all(&u.arrival.to_le_bytes())?;
                    f.write_all(&u.bytes)?;
                }
                for u in &a.buffer {
                    f.write_all(&u.bytes)?;
                }
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and validate from `path`. Accepts versions 1 through the
    /// current one; v1 loads warn that RNG streams are absent, and
    /// pre-v3 loads leave the loss histories empty.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        let nl = all
            .iter()
            .position(|&b| b == b'\n')
            .context("checkpoint missing header line")?;
        let header = Json::parse(std::str::from_utf8(&all[..nl])?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let version = header.get("version").as_usize().unwrap_or(0) as u32;
        if !(1..=VERSION).contains(&version) {
            bail!("unsupported checkpoint version {version}");
        }
        if version == 1 {
            eprintln!(
                "warning: loading v1 checkpoint {path:?} without RNG streams; \
                 stochastic-quantizer algorithms will not resume bit-exactly"
            );
        }
        let dim = header.get("dim").as_usize().context("dim")?;
        let devices = header.get("devices").as_usize().context("devices")?;
        // v6 tracks a (possibly sparse) id subset; earlier versions are
        // dense, so the tracked set is the whole population.
        let device_ids: Vec<usize> = match header.get("ids").as_arr() {
            Some(arr) => arr
                .iter()
                .map(|v| v.as_usize().context("ids"))
                .collect::<Result<_>>()?,
            None => (0..devices).collect(),
        };
        let tracked = device_ids.len();
        let supports: Vec<usize> = header
            .get("supports")
            .as_arr()
            .context("supports")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        if supports.len() != tracked {
            bail!("supports/ids mismatch");
        }
        let mut body = &all[nl + 1..];
        let theta = take_f32s(&mut body, dim)?;
        let prev_theta = take_f32s(&mut body, dim)?;
        let direction = take_f32s(&mut body, dim)?;
        let mut device_q = Vec::with_capacity(tracked);
        for &s in &supports {
            device_q.push(take_f32s(&mut body, s)?);
        }
        let mut device_rng = Vec::new();
        let mut coin_rng = None;
        if version >= 2 {
            for _ in 0..tracked {
                device_rng.push(
                    take_rng(&mut body)?.context("device RNG record marked absent")?,
                );
            }
            coin_rng = take_rng(&mut body)?;
        }
        // v7 buffered-async section: header metadata names the uploads
        // and their byte lengths; the binary tail carries the clock,
        // arrival times, and wire bytes (consumed here, before the
        // trailing-bytes check).
        let async_state = match header.get("async") {
            a @ Json::Obj(_) if version >= 7 => {
                let clock = take_f64(&mut body)?;
                let meta = |v: &Json| -> Result<(usize, usize, usize)> {
                    Ok((
                        v.at(0).as_usize().context("async upload device")?,
                        v.at(1).as_usize().context("async upload version")?,
                        v.at(2).as_usize().context("async upload length")?,
                    ))
                };
                let mut events = Vec::new();
                for v in a.get("events").as_arr().unwrap_or(&[]) {
                    let (device, ver, len) = meta(v)?;
                    let arrival = take_f64(&mut body)?;
                    events.push(AsyncUpload {
                        device,
                        version: ver,
                        arrival,
                        bytes: take_bytes(&mut body, len)?.to_vec(),
                    });
                }
                let mut buffer = Vec::new();
                for v in a.get("buffer").as_arr().unwrap_or(&[]) {
                    let (device, ver, len) = meta(v)?;
                    buffer.push(AsyncUpload {
                        device,
                        version: ver,
                        arrival: 0.0,
                        bytes: take_bytes(&mut body, len)?.to_vec(),
                    });
                }
                let pool = a
                    .get("pool")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| {
                        let level = v.at(3).as_f64().unwrap_or(-1.0);
                        AsyncMember {
                            device: v.at(0).as_usize().unwrap_or(0),
                            version: v.at(1).as_usize().unwrap_or(0),
                            loss: v.at(2).as_f64().unwrap_or(f64::NAN),
                            level: if level < 0.0 { None } else { Some(level as u8) },
                            staged: v.at(4).as_f64().unwrap_or(0.0) != 0.0,
                        }
                    })
                    .collect();
                Some(AsyncState {
                    next_dispatch: a.get("next_dispatch").as_usize().context("async")?,
                    commits: a.get("commits").as_usize().context("async commits")?,
                    clock,
                    last_cohort: a.get("last_cohort").as_usize().unwrap_or(0),
                    fold_round: a.get("fold_round").as_usize().unwrap_or(0),
                    fold_marina_sync: a.get("fold_sync").as_f64().unwrap_or(1.0) != 0.0,
                    pending_bits_up: a.get("pending_up").as_f64().unwrap_or(0.0) as u64,
                    pending_bits_down: a.get("pending_down").as_f64().unwrap_or(0.0)
                        as u64,
                    pending_stragglers: a
                        .get("pending_stragglers")
                        .as_f64()
                        .unwrap_or(0.0) as u64,
                    events,
                    buffer,
                    pool,
                })
            }
            _ => None,
        };
        if !body.is_empty() {
            bail!("trailing bytes in checkpoint");
        }
        // v5 serve-state; absent (None) for older versions and for
        // in-process runs that never served.
        let serve_state = match header.get("serve") {
            Json::Obj(_) => {
                let s = header.get("serve");
                Some(ServeState {
                    clients: s.get("clients").as_usize().unwrap_or(1),
                    staged: s
                        .get("staged")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_f64())
                        .map(|v| v as u32)
                        .collect(),
                })
            }
            _ => None,
        };
        let device_stats = header
            .get("stats")
            .as_arr()
            .context("stats")?
            .iter()
            .map(|v| {
                (
                    v.at(0).as_f64().unwrap_or(0.0) as u64,
                    v.at(1).as_f64().unwrap_or(0.0) as u64,
                    v.at(2).as_f64().unwrap_or(0.0),
                )
            })
            .collect();
        Ok(Checkpoint {
            version,
            round: header.get("round").as_usize().context("round")?,
            population: devices,
            device_ids,
            theta,
            prev_theta,
            direction,
            device_q,
            device_stats,
            device_rng,
            coin_rng,
            diff_history: header
                .get("diff_history")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            // v3 fields; absent (empty) in v1/v2 headers. Nulls encode
            // NaN (never-observed losses).
            loss_history: header
                .get("loss_history")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN))
                .collect(),
            device_last_loss: header
                .get("device_last_loss")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN))
                .collect(),
            cum_bits: header.get("cum_bits").as_f64().unwrap_or(0.0) as u64,
            // v4 network accounting; absent (zero) in older headers.
            bits_down: header.get("bits_down").as_f64().unwrap_or(0.0) as u64,
            sim_time: header.get("sim_time").as_f64().unwrap_or(0.0),
            stragglers: header.get("stragglers").as_f64().unwrap_or(0.0) as u64,
            init_loss: header.get("init_loss").as_f64().unwrap_or(f64::NAN),
            prev_loss: header.get("prev_loss").as_f64().unwrap_or(f64::NAN),
            serve_state,
            async_state,
        })
    }
}

fn write_f32s(f: &mut std::fs::File, xs: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)
}

fn write_rng(f: &mut std::fs::File, rng: Option<&RngState>) -> std::io::Result<()> {
    let mut buf = [0u8; RNG_RECORD_BYTES];
    if let Some(r) = rng {
        for (i, w) in r.s.iter().enumerate() {
            buf[i * 8..(i + 1) * 8].copy_from_slice(&w.to_le_bytes());
        }
        buf[32] = 1;
        if let Some(g) = r.gauss_cache {
            buf[33] = 1;
            buf[34..42].copy_from_slice(&g.to_le_bytes());
        }
    }
    f.write_all(&buf)
}

fn take_bytes<'a>(body: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if body.len() < n {
        bail!("checkpoint body truncated");
    }
    let (head, rest) = body.split_at(n);
    *body = rest;
    Ok(head)
}

fn take_f32s(body: &mut &[u8], n: usize) -> Result<Vec<f32>> {
    Ok(take_bytes(body, n * 4)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Read one raw little-endian `f64` (v7 async section: clock and
/// arrival times travel as bits, never as JSON text).
fn take_f64(body: &mut &[u8]) -> Result<f64> {
    Ok(f64::from_le_bytes(take_bytes(body, 8)?.try_into().unwrap()))
}

/// Read one RNG record; `Ok(None)` for an absent-marked record.
fn take_rng(body: &mut &[u8]) -> Result<Option<RngState>> {
    let rec = take_bytes(body, RNG_RECORD_BYTES)?;
    if rec[32] == 0 {
        return Ok(None);
    }
    let mut s = [0u64; 4];
    for (i, w) in s.iter_mut().enumerate() {
        *w = u64::from_le_bytes(rec[i * 8..(i + 1) * 8].try_into().unwrap());
    }
    let gauss_cache = if rec[33] == 1 {
        Some(f64::from_le_bytes(rec[34..42].try_into().unwrap()))
    } else {
        None
    };
    Ok(Some(RngState { s, gauss_cache }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            version: VERSION,
            round: 42,
            population: 2,
            device_ids: vec![0, 1],
            theta: vec![1.0, -2.5, 3.25],
            prev_theta: vec![0.5, -2.0, 3.0],
            direction: vec![0.1, 0.2, 0.3],
            device_q: vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0]],
            device_stats: vec![(10, 2, 0.125), (8, 4, 0.5)],
            device_rng: vec![
                RngState {
                    s: [1, 2, 3, 4],
                    gauss_cache: None,
                },
                RngState {
                    s: [u64::MAX, 7, 8, 9],
                    gauss_cache: Some(-0.75),
                },
            ],
            coin_rng: Some(RngState {
                s: [11, 12, 13, 14],
                gauss_cache: None,
            }),
            diff_history: vec![0.5, 0.25],
            loss_history: vec![0.8, 0.9, 1.1],
            device_last_loss: vec![0.7, f64::NAN],
            cum_bits: 123_456,
            bits_down: 654_321,
            sim_time: 12.5,
            stragglers: 3,
            init_loss: 2.5,
            prev_loss: 0.75,
            serve_state: Some(ServeState {
                clients: 2,
                staged: vec![0, 1],
            }),
            async_state: None,
        }
    }

    fn sample_async() -> AsyncState {
        AsyncState {
            next_dispatch: 5,
            commits: 3,
            clock: 17.25f64.powi(3) / 7.0, // not exactly representable in short decimal
            last_cohort: 2,
            fold_round: 4,
            fold_marina_sync: false,
            pending_bits_up: 1_024,
            pending_bits_down: 4_096,
            pending_stragglers: 1,
            events: vec![
                AsyncUpload {
                    device: 1,
                    version: 4,
                    arrival: 19.5 + f64::EPSILON,
                    bytes: vec![1, 2, 3, 4, 5],
                },
                AsyncUpload {
                    device: 0,
                    version: 3,
                    arrival: 18.0,
                    bytes: vec![9, 8],
                },
            ],
            buffer: vec![AsyncUpload {
                device: 1,
                version: 3,
                arrival: 0.0,
                bytes: vec![7; 11],
            }],
            pool: vec![
                AsyncMember {
                    device: 0,
                    version: 3,
                    loss: 0.5,
                    level: Some(4),
                    staged: true,
                },
                AsyncMember {
                    device: 1,
                    version: 4,
                    loss: 0.25,
                    level: None,
                    staged: false,
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("aquila_ckpt_test");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        // NaN breaks PartialEq; exercise it separately below.
        c.device_last_loss = vec![0.7, 0.6];
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, c);
        assert_eq!(loaded.version, VERSION);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_histories_roundtrip_with_nan() {
        let dir = std::env::temp_dir().join("aquila_ckpt_v3");
        let path = dir.join("run.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.loss_history, c.loss_history);
        assert_eq!(loaded.device_last_loss[0], 0.7);
        assert!(loaded.device_last_loss[1].is_nan());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_header_without_histories_loads_empty() {
        // Simulate an old v2 checkpoint: strip the v3 keys and rewrite
        // the version field.
        let dir = std::env::temp_dir().join("aquila_ckpt_v2compat");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.device_last_loss = vec![0.1, 0.2];
        c.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = String::from_utf8(bytes[..nl].to_vec()).unwrap();
        let mut j = crate::util::json::Json::parse(&header).unwrap();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("loss_history");
            m.remove("device_last_loss");
            m.remove("ids");
            m.insert("version".into(), crate::util::json::Json::Num(2.0));
        }
        let mut rewritten = j.to_string().into_bytes();
        rewritten.push(b'\n');
        rewritten.extend_from_slice(&bytes[nl + 1..]);
        std::fs::write(&path, rewritten).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.version, 2);
        assert!(loaded.loss_history.is_empty());
        assert!(loaded.device_last_loss.is_empty());
        // Pre-v6 headers have no `ids`: every device is tracked.
        assert_eq!(loaded.device_ids, vec![0, 1]);
        assert_eq!(loaded.population, 2);
        assert_eq!(loaded.theta, c.theta);
        assert_eq!(loaded.device_rng, c.device_rng);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v5_dense_header_loads_all_tracked() {
        // A v5 checkpoint is exactly a v6 one minus the `ids` key, with
        // `devices` meaning the tracked count: the dense→sparse
        // migration must track device 0..devices.
        let dir = std::env::temp_dir().join("aquila_ckpt_v5compat");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.device_last_loss = vec![0.1, 0.2];
        c.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = String::from_utf8(bytes[..nl].to_vec()).unwrap();
        let mut j = crate::util::json::Json::parse(&header).unwrap();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("ids");
            m.insert("version".into(), crate::util::json::Json::Num(5.0));
        }
        let mut rewritten = j.to_string().into_bytes();
        rewritten.push(b'\n');
        rewritten.extend_from_slice(&bytes[nl + 1..]);
        std::fs::write(&path, rewritten).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.version, 5);
        assert_eq!(loaded.population, 2);
        assert_eq!(loaded.device_ids, vec![0, 1]);
        assert_eq!(loaded.device_q, c.device_q);
        assert_eq!(loaded.device_rng, c.device_rng);
        assert_eq!(loaded.serve_state, c.serve_state);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_ids_roundtrip() {
        // A virtualized run tracks only the devices it materialized:
        // the id list, not the population size, keys the binary
        // sections.
        let dir = std::env::temp_dir().join("aquila_ckpt_sparse");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.population = 100;
        c.device_ids = vec![3, 17];
        c.device_last_loss = vec![0.7, 0.6];
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, c);
        assert_eq!(loaded.population, 100);
        assert_eq!(loaded.device_ids, vec![3, 17]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_without_rng_still_loads() {
        let dir = std::env::temp_dir().join("aquila_ckpt_v1");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        // No RNG streams: saves in v1 layout.
        c.device_rng.clear();
        c.coin_rng = None;
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.version, 1);
        assert!(loaded.device_rng.is_empty());
        assert_eq!(loaded.coin_rng, None);
        assert_eq!(loaded.theta, c.theta);
        assert_eq!(loaded.device_q, c.device_q);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nan_losses_roundtrip_as_null() {
        // A pre-first-round snapshot (or a run whose sparse selection
        // left round 0 without participants) has NaN loss estimates.
        let dir = std::env::temp_dir().join("aquila_ckpt_nan");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.init_loss = f64::NAN;
        c.prev_loss = f64::NAN;
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert!(loaded.init_loss.is_nan());
        assert!(loaded.prev_loss.is_nan());
        assert_eq!(loaded.theta, c.theta);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_state_is_optional() {
        // In-process runs never set it; the header simply has no
        // `serve` key and loads back as None.
        let dir = std::env::temp_dir().join("aquila_ckpt_serve_none");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.serve_state = None;
        c.device_last_loss = vec![0.7, 0.6];
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.serve_state, None);
        assert_eq!(loaded, c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_state_roundtrips_bit_exact() {
        // v7: events (with their wire bytes and arrival-time bits),
        // the partial buffer, the member pool, and the retained fold
        // context all survive a save/load cycle exactly.
        let dir = std::env::temp_dir().join("aquila_ckpt_async");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.device_last_loss = vec![0.7, 0.6];
        c.async_state = Some(sample_async());
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, c);
        let a = loaded.async_state.unwrap();
        let b = sample_async();
        assert_eq!(a.clock.to_bits(), b.clock.to_bits());
        assert_eq!(a.events[0].arrival.to_bits(), b.events[0].arrival.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_pool_nan_loss_roundtrips() {
        // A pool member that never reported (remote path) carries a
        // NaN loss; it must survive as NaN, not poison the header.
        let dir = std::env::temp_dir().join("aquila_ckpt_async_nan");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        let mut a = sample_async();
        a.pool[1].loss = f64::NAN;
        c.async_state = Some(a);
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let a = loaded.async_state.unwrap();
        assert!(a.pool[1].loss.is_nan());
        assert_eq!(a.pool[0].loss, 0.5);
        assert_eq!(a.pool[1].level, None);
        assert_eq!(a.pool[0].level, Some(4));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sync_checkpoints_have_no_async_section() {
        // The sync path never materializes buffered state; the header
        // has no `async` key and loads back as None (as do all pre-v7
        // checkpoints, which cannot contain one).
        let dir = std::env::temp_dir().join("aquila_ckpt_async_none");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.device_last_loss = vec![0.7, 0.6];
        c.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        assert!(!String::from_utf8_lossy(&bytes[..nl]).contains("\"async\""));
        assert_eq!(Checkpoint::load(&path).unwrap().async_state, None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncation() {
        let dir = std::env::temp_dir().join("aquila_ckpt_trunc");
        let path = dir.join("run.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 4);
        std::fs::write(&path, bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_version() {
        let dir = std::env::temp_dir().join("aquila_ckpt_ver");
        let path = dir.join("run.ckpt");
        sample().save(&path).unwrap();
        let text = std::fs::read(&path).unwrap();
        let s = String::from_utf8_lossy(&text)
            .replace(&format!("\"version\":{VERSION}"), "\"version\":9");
        std::fs::write(&path, s).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(Checkpoint::load(Path::new("/nonexistent/x.ckpt")).is_err());
    }
}
