//! Run checkpointing: persist and restore the full coordinator state
//! (global model, per-device lazy-aggregation state, counters) so long
//! table sweeps and the e2e training run survive interruption.
//!
//! Format: a JSON header line (versioned, with dims for validation)
//! followed by raw little-endian `f32` sections. Written atomically
//! (temp file + rename).

use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Serializable snapshot of a run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Format version.
    pub version: u32,
    /// Next round index to execute.
    pub round: usize,
    /// Global model `θ`.
    pub theta: Vec<f32>,
    /// Previous-round model (for `‖θᵏ − θ^{k−1}‖²`).
    pub prev_theta: Vec<f32>,
    /// Server direction / running `q̄`.
    pub direction: Vec<f32>,
    /// Per-device stored reference vectors `q_m` (gathered space).
    pub device_q: Vec<Vec<f32>>,
    /// Per-device `(uploads, skips, prev_err_sq)`.
    pub device_stats: Vec<(u64, u64, f64)>,
    /// Model-difference history, most recent first.
    pub diff_history: Vec<f64>,
    /// Cumulative uplink bits.
    pub cum_bits: u64,
    /// Loss estimates.
    pub init_loss: f64,
    pub prev_loss: f64,
}

const VERSION: u32 = 1;

impl Checkpoint {
    /// Write atomically to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = obj(vec![
            ("version", Json::Num(VERSION as f64)),
            ("round", Json::Num(self.round as f64)),
            ("dim", Json::Num(self.theta.len() as f64)),
            ("devices", Json::Num(self.device_q.len() as f64)),
            (
                "supports",
                Json::Arr(
                    self.device_q
                        .iter()
                        .map(|q| Json::Num(q.len() as f64))
                        .collect(),
                ),
            ),
            (
                "stats",
                Json::Arr(
                    self.device_stats
                        .iter()
                        .map(|&(u, s, e)| {
                            Json::Arr(vec![
                                Json::Num(u as f64),
                                Json::Num(s as f64),
                                Json::Num(e),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diff_history",
                Json::Arr(self.diff_history.iter().map(|&d| Json::Num(d)).collect()),
            ),
            ("cum_bits", Json::Num(self.cum_bits as f64)),
            ("init_loss", Json::Num(self.init_loss)),
            ("prev_loss", Json::Num(self.prev_loss)),
        ]);
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            writeln!(f, "{header}")?;
            write_f32s(&mut f, &self.theta)?;
            write_f32s(&mut f, &self.prev_theta)?;
            write_f32s(&mut f, &self.direction)?;
            for q in &self.device_q {
                write_f32s(&mut f, q)?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and validate from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        let nl = all
            .iter()
            .position(|&b| b == b'\n')
            .context("checkpoint missing header line")?;
        let header = Json::parse(std::str::from_utf8(&all[..nl])?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let version = header.get("version").as_usize().unwrap_or(0) as u32;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let dim = header.get("dim").as_usize().context("dim")?;
        let devices = header.get("devices").as_usize().context("devices")?;
        let supports: Vec<usize> = header
            .get("supports")
            .as_arr()
            .context("supports")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        if supports.len() != devices {
            bail!("supports/devices mismatch");
        }
        let mut body = &all[nl + 1..];
        let mut take = |n: usize| -> Result<Vec<f32>> {
            let bytes = n * 4;
            if body.len() < bytes {
                bail!("checkpoint body truncated");
            }
            let (head, rest) = body.split_at(bytes);
            body = rest;
            Ok(head
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        };
        let theta = take(dim)?;
        let prev_theta = take(dim)?;
        let direction = take(dim)?;
        let mut device_q = Vec::with_capacity(devices);
        for &s in &supports {
            device_q.push(take(s)?);
        }
        if !body.is_empty() {
            bail!("trailing bytes in checkpoint");
        }
        let device_stats = header
            .get("stats")
            .as_arr()
            .context("stats")?
            .iter()
            .map(|v| {
                (
                    v.at(0).as_f64().unwrap_or(0.0) as u64,
                    v.at(1).as_f64().unwrap_or(0.0) as u64,
                    v.at(2).as_f64().unwrap_or(0.0),
                )
            })
            .collect();
        Ok(Checkpoint {
            version,
            round: header.get("round").as_usize().context("round")?,
            theta,
            prev_theta,
            direction,
            device_q,
            device_stats,
            diff_history: header
                .get("diff_history")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            cum_bits: header.get("cum_bits").as_f64().unwrap_or(0.0) as u64,
            init_loss: header.get("init_loss").as_f64().unwrap_or(f64::NAN),
            prev_loss: header.get("prev_loss").as_f64().unwrap_or(f64::NAN),
        })
    }
}

fn write_f32s(f: &mut std::fs::File, xs: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            version: VERSION,
            round: 42,
            theta: vec![1.0, -2.5, 3.25],
            prev_theta: vec![0.5, -2.0, 3.0],
            direction: vec![0.1, 0.2, 0.3],
            device_q: vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0]],
            device_stats: vec![(10, 2, 0.125), (8, 4, 0.5)],
            diff_history: vec![0.5, 0.25],
            cum_bits: 123_456,
            init_loss: 2.5,
            prev_loss: 0.75,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("aquila_ckpt_test");
        let path = dir.join("run.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncation() {
        let dir = std::env::temp_dir().join("aquila_ckpt_trunc");
        let path = dir.join("run.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 4);
        std::fs::write(&path, bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_version() {
        let dir = std::env::temp_dir().join("aquila_ckpt_ver");
        let path = dir.join("run.ckpt");
        sample().save(&path).unwrap();
        let text = std::fs::read(&path).unwrap();
        let s = String::from_utf8_lossy(&text).replace("\"version\":1", "\"version\":9");
        std::fs::write(&path, s).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(Checkpoint::load(Path::new("/nonexistent/x.ckpt")).is_err());
    }
}
