//! The round engine: every piece of mutable run state plus the round
//! protocol, independent of *how* the problem/algorithm/strategy are
//! owned. The owned [`super::Session`] and the deprecated borrowed
//! [`super::Coordinator`] are both thin front-ends over this type.

use super::checkpoint::{Checkpoint, RngState, VERSION};
use super::RunConfig;
use crate::algorithms::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::hetero::CapacityMask;
use crate::metrics::RoundRecord;
use crate::problems::GradientSource;
use crate::quant::levels::DadaquantSchedule;
use crate::selection::{DeviceView, Selection, SelectionStrategy, SelectionView};
use crate::transport::wire::Payload;
use crate::transport::Channel;
use crate::util::pool::parallel_for_each_mut;
use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::{axpy, diff_norm2_sq};
use std::collections::VecDeque;
use std::sync::Arc;

/// Per-device slot: algorithm state + reusable buffers + per-round
/// staging, kept together so one thread owns the whole cache line set.
struct DeviceSlot {
    state: DeviceState,
    grad_full: Vec<f32>,
    grad_gathered: Vec<f32>,
    staged: Option<Payload>,
    staged_level: Option<u8>,
    loss: f64,
    participated: bool,
}

/// Mutable run state + the round protocol (steps 1–5 of the module docs
/// in `crate::coordinator`). Problem, algorithm, and selection strategy
/// are passed per call so front-ends may own them however they like.
pub struct RoundEngine {
    cfg: RunConfig,
    slots: Vec<DeviceSlot>,
    server: ServerAgg,
    theta: Vec<f32>,
    prev_theta: Vec<f32>,
    channel: Channel,
    diff_history: VecDeque<f64>,
    /// Recent global train losses, most recent first (selection view).
    loss_history: VecDeque<f64>,
    /// Per-device statistics exposed to selection strategies.
    device_views: Vec<DeviceView>,
    init_loss: f64,
    prev_loss: f64,
    coin_rng: Xoshiro256pp,
    dadaquant: DadaquantSchedule,
    threads: usize,
    cum_bits: u64,
}

impl RoundEngine {
    /// Build the engine for `problem` with explicit per-device masks.
    pub fn new(
        problem: &dyn GradientSource,
        masks: Vec<Arc<CapacityMask>>,
        cfg: RunConfig,
    ) -> Self {
        let d = problem.dim();
        let m = problem.num_devices();
        assert_eq!(masks.len(), m, "need one mask per device");
        for mask in &masks {
            assert_eq!(mask.full_dim, d);
        }
        let theta = problem.init_theta(cfg.seed);
        let slots = masks
            .iter()
            .enumerate()
            .map(|(i, mask)| DeviceSlot {
                state: DeviceState::new(i, mask.clone(), cfg.seed),
                grad_full: vec![0.0; d],
                grad_gathered: Vec::with_capacity(mask.support()),
                staged: None,
                staged_level: None,
                loss: 0.0,
                participated: false,
            })
            .collect();
        let threads = if cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            cfg.threads
        };
        Self {
            server: ServerAgg::new(d, masks),
            slots,
            prev_theta: theta.clone(),
            theta,
            channel: Channel::new(cfg.faults.clone()),
            diff_history: VecDeque::with_capacity(cfg.history_depth + 1),
            loss_history: VecDeque::with_capacity(cfg.history_depth + 1),
            device_views: vec![DeviceView::default(); m],
            init_loss: f64::NAN,
            prev_loss: f64::NAN,
            coin_rng: Xoshiro256pp::stream(cfg.seed, 0xC011),
            dadaquant: DadaquantSchedule::new(2, 3, 16),
            threads,
            cfg,
            cum_bits: 0,
        }
    }

    /// Run configuration this engine was built with.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Current global model.
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Cumulative uplink bits so far (survives checkpoint restore,
    /// unlike the channel's own since-construction counter).
    pub fn total_bits(&self) -> u64 {
        self.cum_bits
    }

    /// Per-device upload/skip counters.
    pub fn device_stats(&self) -> Vec<(u64, u64)> {
        self.slots
            .iter()
            .map(|s| (s.state.uploads, s.state.skips))
            .collect()
    }

    fn build_ctx(&mut self, round: usize, strategy: &mut dyn SelectionStrategy) -> RoundCtx {
        let m = self.slots.len();
        let model_diff_sq = self.diff_history.front().copied().unwrap_or(0.0);
        let loss_history: Vec<f64> = self.loss_history.iter().copied().collect();
        let view = SelectionView {
            round,
            num_devices: m,
            devices: &self.device_views,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
            loss_history: &loss_history,
        };
        let selected = match strategy.select(&view) {
            Selection::All => None,
            Selection::Devices(mut ids) => {
                // `RoundCtx::is_selected` binary-searches: sorted,
                // deduped, in-range.
                ids.retain(|&i| i < m);
                ids.sort_unstable();
                ids.dedup();
                Some(ids)
            }
        };
        let dadaquant_level = if round == 0 || self.prev_loss.is_nan() {
            self.dadaquant.level()
        } else {
            self.dadaquant.observe(self.prev_loss)
        };
        RoundCtx {
            round,
            num_devices: m,
            alpha: self.cfg.alpha,
            beta: self.cfg.beta,
            model_diff_sq,
            model_diff_history: self.diff_history.iter().copied().collect(),
            init_loss: if self.init_loss.is_nan() { 1.0 } else { self.init_loss },
            prev_loss: if self.prev_loss.is_nan() { 1.0 } else { self.prev_loss },
            marina_sync: round == 0 || self.coin_rng.bernoulli(self.cfg.marina_p_sync),
            selected,
            dadaquant_level,
        }
    }

    /// Execute one communication round; returns its record.
    pub fn run_round(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        strategy: &mut dyn SelectionStrategy,
        round: usize,
    ) -> RoundRecord {
        let ctx = self.build_ctx(round, strategy);
        let theta = &self.theta;

        // ---- device phase (parallel) ---------------------------------
        parallel_for_each_mut(&mut self.slots, self.threads, |i, slot| {
            slot.staged = None;
            slot.staged_level = None;
            slot.participated = ctx.is_selected(i);
            if !slot.participated {
                // Unselected devices neither compute nor consult the
                // algorithm: participation is the engine's concern,
                // not part of the `Algorithm` client contract (most
                // client rules assume a full-length gradient).
                return;
            }
            slot.loss = problem.local_grad(i, theta, &mut slot.grad_full);
            slot.state.mask.gather(&slot.grad_full, &mut slot.grad_gathered);
            let ClientUpload { payload, level } =
                algo.client_step(&mut slot.state, &slot.grad_gathered, &ctx);
            slot.staged = payload;
            slot.staged_level = level;
        });

        // ---- transport phase ------------------------------------------
        let uploads: Vec<(usize, Payload)> = self
            .slots
            .iter_mut()
            .filter_map(|s| s.staged.take().map(|p| (s.state.id, p)))
            .collect();
        let upload_count = uploads.len();
        let (delivered, stats) = self.channel.transmit(uploads);

        // ---- server phase ---------------------------------------------
        algo.server_fold(&mut self.server, &delivered, &ctx);
        self.prev_theta.copy_from_slice(&self.theta);
        axpy(-self.cfg.alpha, &self.server.direction, &mut self.theta);
        let diff = diff_norm2_sq(&self.theta, &self.prev_theta);
        self.diff_history.push_front(diff);
        while self.diff_history.len() > self.cfg.history_depth {
            self.diff_history.pop_back();
        }

        // ---- metrics ----------------------------------------------------
        let participants: Vec<&DeviceSlot> =
            self.slots.iter().filter(|s| s.participated).collect();
        let train_loss = if participants.is_empty() {
            self.prev_loss
        } else {
            participants.iter().map(|s| s.loss).sum::<f64>() / participants.len() as f64
        };
        // First *observed* loss anchors f(θ⁰): with sparse selection
        // (availability schedules) round 0 may have no participants,
        // and a NaN anchor would poison AdaQuantFL's level rule for
        // the whole run.
        if self.init_loss.is_nan() && train_loss.is_finite() {
            self.init_loss = train_loss;
        }
        self.prev_loss = train_loss;
        self.loss_history.push_front(train_loss);
        while self.loss_history.len() > self.cfg.history_depth {
            self.loss_history.pop_back();
        }
        let levels: Vec<u8> = self
            .slots
            .iter()
            .filter_map(|s| s.staged_level)
            .collect();
        let mean_level = if levels.is_empty() {
            0.0
        } else {
            levels.iter().map(|&b| b as f64).sum::<f64>() / levels.len() as f64
        };
        self.cum_bits += stats.uplink_bits;
        for (view, slot) in self.device_views.iter_mut().zip(&self.slots) {
            view.uploads = slot.state.uploads;
            view.skips = slot.state.skips;
            if slot.participated {
                view.last_loss = Some(slot.loss);
            }
        }
        let do_eval = (self.cfg.eval_every > 0 && round.is_multiple_of(self.cfg.eval_every))
            || round + 1 == self.cfg.rounds;
        let (eval_loss, accuracy, perplexity) = if do_eval {
            let ev = problem.eval(&self.theta);
            (Some(ev.loss), ev.accuracy, ev.perplexity)
        } else {
            (None, None, None)
        };
        RoundRecord {
            round,
            bits_up: stats.uplink_bits,
            cum_bits: self.cum_bits,
            uploads: upload_count,
            skips: participants.len().saturating_sub(upload_count),
            mean_level,
            train_loss,
            eval_loss,
            accuracy,
            perplexity,
        }
    }

    /// Snapshot the run state (resume with [`RoundEngine::restore`]).
    /// `next_round` is the index of the first round not yet executed.
    pub fn snapshot(&self, next_round: usize) -> Checkpoint {
        let rng_state = |rng: &Xoshiro256pp| {
            let (s, gauss_cache) = rng.snapshot();
            RngState { s, gauss_cache }
        };
        Checkpoint {
            version: VERSION,
            round: next_round,
            theta: self.theta.clone(),
            prev_theta: self.prev_theta.clone(),
            direction: self.server.direction.clone(),
            device_q: self.slots.iter().map(|s| s.state.q_prev.clone()).collect(),
            device_stats: self
                .slots
                .iter()
                .map(|s| (s.state.uploads, s.state.skips, s.state.prev_err_sq))
                .collect(),
            device_rng: self.slots.iter().map(|s| rng_state(&s.state.rng)).collect(),
            coin_rng: Some(rng_state(&self.coin_rng)),
            diff_history: self.diff_history.iter().copied().collect(),
            cum_bits: self.cum_bits,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
        }
    }

    /// Restore a snapshot produced by [`RoundEngine::snapshot`] on an
    /// engine built with the same problem/masks/config. Returns the
    /// next round index to execute.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<usize> {
        anyhow::ensure!(
            ckpt.theta.len() == self.theta.len(),
            "checkpoint dim {} != model dim {}",
            ckpt.theta.len(),
            self.theta.len()
        );
        anyhow::ensure!(
            ckpt.device_q.len() == self.slots.len(),
            "checkpoint device count mismatch"
        );
        for (slot, q) in self.slots.iter().zip(&ckpt.device_q) {
            anyhow::ensure!(
                slot.state.q_prev.len() == q.len(),
                "device {} support mismatch",
                slot.state.id
            );
        }
        self.theta.copy_from_slice(&ckpt.theta);
        self.prev_theta.copy_from_slice(&ckpt.prev_theta);
        self.server.direction.copy_from_slice(&ckpt.direction);
        for (slot, (q, &(u, s, e))) in self
            .slots
            .iter_mut()
            .zip(ckpt.device_q.iter().zip(&ckpt.device_stats))
        {
            slot.state.q_prev.copy_from_slice(q);
            slot.state.uploads = u;
            slot.state.skips = s;
            slot.state.prev_err_sq = e;
        }
        // RNG streams (v2 checkpoints; v1 keeps fresh streams and
        // `Checkpoint::load` already warned).
        if ckpt.device_rng.len() == self.slots.len() {
            for (slot, rng) in self.slots.iter_mut().zip(&ckpt.device_rng) {
                slot.state.rng = Xoshiro256pp::from_snapshot(rng.s, rng.gauss_cache);
            }
        }
        if let Some(coin) = &ckpt.coin_rng {
            self.coin_rng = Xoshiro256pp::from_snapshot(coin.s, coin.gauss_cache);
        }
        for (view, slot) in self.device_views.iter_mut().zip(&self.slots) {
            view.uploads = slot.state.uploads;
            view.skips = slot.state.skips;
            view.last_loss = None;
        }
        self.diff_history = ckpt.diff_history.iter().copied().collect();
        self.loss_history.clear();
        self.cum_bits = ckpt.cum_bits;
        self.init_loss = ckpt.init_loss;
        self.prev_loss = ckpt.prev_loss;
        Ok(ckpt.round)
    }
}
