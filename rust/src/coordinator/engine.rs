//! The round engine: every piece of mutable run state plus the round
//! protocol, independent of *how* the problem/algorithm/strategy are
//! owned. The owned [`super::Session`] is a thin front-end over this
//! type.

use super::checkpoint::{Checkpoint, RngState, VERSION};
use super::RunConfig;
use crate::algorithms::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::hetero::CapacityMask;
use crate::metrics::RoundRecord;
use crate::problems::{GradScratch, GradientSource};
use crate::quant::levels::DadaquantSchedule;
use crate::selection::{DeviceView, Selection, SelectionStrategy, SelectionView};
use crate::transport::scenario::NetworkScenario;
use crate::transport::wire::{self, UploadRef};
use crate::transport::Channel;
use crate::util::pool::parallel_for_cohort;
use crate::util::ring::RecentWindow;
use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::{axpy, diff_norm2_sq};
use std::sync::Arc;

/// Per-device slot: algorithm state + per-round staging, kept together
/// so one thread owns the whole cache line set. Gradient working
/// buffers live in [`WorkerScratch`] (one per worker thread, not one
/// per device), so engine memory is O(threads·d) + per-device state
/// instead of O(M·d) of scratch.
struct DeviceSlot {
    state: DeviceState,
    /// This round's serialized upload (valid when `staged`); encoded in
    /// the parallel device phase and read zero-copy by the server fold.
    /// Persists across rounds so encoding stops allocating after round 0.
    wire_buf: Vec<u8>,
    staged: bool,
    staged_level: Option<u8>,
    loss: f64,
    participated: bool,
}

/// Gradient working set owned by one device-phase worker thread and
/// reused across the devices in its cohort chunk (and across rounds).
/// Every buffer is fully overwritten per device (`local_grad` fills the
/// whole gradient, `gather` clears before extending), so sharing scratch
/// across devices cannot change any device's result.
struct WorkerScratch {
    grad_full: Vec<f32>,
    grad_gathered: Vec<f32>,
    /// Gradient workspace (activations, deltas, softmax staging) owned
    /// by the worker so the batched `local_grad` passes allocate nothing
    /// in steady state.
    scratch: GradScratch,
}

/// Mutable run state + the round protocol (steps 1–5 of the module docs
/// in `crate::coordinator`). Problem, algorithm, and selection strategy
/// are passed per call so front-ends may own them however they like.
pub struct RoundEngine {
    cfg: RunConfig,
    slots: Vec<DeviceSlot>,
    /// One gradient working set per worker thread (see [`WorkerScratch`]).
    workers: Vec<WorkerScratch>,
    server: ServerAgg,
    theta: Vec<f32>,
    prev_theta: Vec<f32>,
    channel: Channel,
    /// Recent squared model differences, most recent first.
    diff_history: RecentWindow,
    /// Recent global train losses, most recent first (selection view;
    /// persisted since checkpoint v3 so post-restore selection matches
    /// the uninterrupted run).
    loss_history: RecentWindow,
    /// Recycled buffer for `RoundCtx::model_diff_history` (the context
    /// hands it back at the end of every round — no per-round allocation).
    ctx_diff_buf: Vec<f64>,
    /// Per-device statistics exposed to selection strategies.
    device_views: Vec<DeviceView>,
    init_loss: f64,
    prev_loss: f64,
    coin_rng: Xoshiro256pp,
    dadaquant: DadaquantSchedule,
    cum_bits: u64,
    /// Cumulative downlink (broadcast) bits.
    cum_bits_down: u64,
    /// Cumulative simulated wall-clock seconds.
    cum_sim_time: f64,
    /// Cumulative deadline-missing uploads.
    cum_stragglers: u64,
    /// Recycled buffer of this round's participant device ids
    /// (downlink billing + per-device link lookup in the channel).
    participant_buf: Vec<usize>,
}

impl RoundEngine {
    /// Build the engine for `problem` with explicit per-device masks.
    pub fn new(
        problem: &dyn GradientSource,
        masks: Vec<Arc<CapacityMask>>,
        cfg: RunConfig,
    ) -> Self {
        let d = problem.dim();
        let m = problem.num_devices();
        assert_eq!(masks.len(), m, "need one mask per device");
        for mask in &masks {
            assert_eq!(mask.full_dim, d);
        }
        let theta = problem.init_theta(cfg.seed);
        // Resolve each device's quantization sections once, from the
        // problem's layout × the run's `quant_sections` spec × the
        // device's capacity mask. Devices sharing a mask share the
        // resolved `Sections` (HeteroFL setups hand out two masks to M
        // devices, not M distinct ones).
        let layout = problem.layout();
        let mut section_cache: Vec<(*const CapacityMask, Arc<crate::quant::Sections>)> =
            Vec::new();
        let mut sections_for = |mask: &Arc<CapacityMask>| {
            let key = Arc::as_ptr(mask);
            if let Some((_, s)) = section_cache.iter().find(|(k, _)| *k == key) {
                return s.clone();
            }
            let s = Arc::new(cfg.quant_sections.resolve(&layout, mask));
            section_cache.push((key, s.clone()));
            s
        };
        let slots = masks
            .iter()
            .enumerate()
            .map(|(i, mask)| DeviceSlot {
                state: DeviceState::with_sections(
                    i,
                    mask.clone(),
                    sections_for(mask),
                    cfg.seed,
                ),
                wire_buf: Vec::new(),
                staged: false,
                staged_level: None,
                loss: 0.0,
                participated: false,
            })
            .collect();
        let threads = if cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            cfg.threads
        };
        let workers = (0..threads.max(1).min(m.max(1)))
            .map(|_| WorkerScratch {
                grad_full: vec![0.0; d],
                grad_gathered: Vec::new(),
                scratch: problem.make_scratch(),
            })
            .collect();
        let mut server = ServerAgg::new(d, masks);
        server.set_threads(threads);
        // Per-device links are drawn from the run seed, so the fleet —
        // like every other stochastic component — is reproducible.
        let channel =
            Channel::with_scenario(cfg.faults.clone(), cfg.network.build(m, cfg.seed));
        Self {
            server,
            slots,
            workers,
            prev_theta: theta.clone(),
            theta,
            channel,
            diff_history: RecentWindow::new(cfg.history_depth),
            loss_history: RecentWindow::new(cfg.history_depth),
            ctx_diff_buf: Vec::with_capacity(cfg.history_depth + 1),
            device_views: vec![DeviceView::default(); m],
            init_loss: f64::NAN,
            prev_loss: f64::NAN,
            coin_rng: Xoshiro256pp::stream(cfg.seed, 0xC011),
            dadaquant: DadaquantSchedule::new(
                cfg.dadaquant_b0,
                cfg.dadaquant_patience,
                cfg.dadaquant_cap,
            ),
            cfg,
            cum_bits: 0,
            cum_bits_down: 0,
            cum_sim_time: 0.0,
            cum_stragglers: 0,
            participant_buf: Vec::with_capacity(m),
        }
    }

    /// Run configuration this engine was built with.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Current global model.
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Cumulative uplink bits so far (survives checkpoint restore,
    /// unlike the channel's own since-construction counter).
    pub fn total_bits(&self) -> u64 {
        self.cum_bits
    }

    /// Cumulative downlink (broadcast) bits so far.
    pub fn total_bits_down(&self) -> u64 {
        self.cum_bits_down
    }

    /// Cumulative simulated wall-clock seconds so far (0 over the
    /// ideal network).
    pub fn total_sim_time(&self) -> f64 {
        self.cum_sim_time
    }

    /// Cumulative deadline-missing uploads so far.
    pub fn total_stragglers(&self) -> u64 {
        self.cum_stragglers
    }

    /// The simulated network scenario this engine runs over.
    pub fn network(&self) -> &NetworkScenario {
        self.channel.scenario()
    }

    /// Per-device upload/skip counters.
    pub fn device_stats(&self) -> Vec<(u64, u64)> {
        self.slots
            .iter()
            .map(|s| (s.state.uploads, s.state.skips))
            .collect()
    }

    fn build_ctx(&mut self, round: usize, strategy: &mut dyn SelectionStrategy) -> RoundCtx {
        let m = self.slots.len();
        let model_diff_sq = self.diff_history.latest().unwrap_or(0.0);
        let view = SelectionView {
            round,
            num_devices: m,
            devices: &self.device_views,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
            loss_history: self.loss_history.as_slice(),
        };
        let selected = match strategy.select(&view) {
            Selection::All => None,
            Selection::Devices(mut ids) => {
                // `RoundCtx::is_selected` binary-searches: sorted,
                // deduped, in-range.
                ids.retain(|&i| i < m);
                ids.sort_unstable();
                ids.dedup();
                Some(ids)
            }
        };
        let dadaquant_level = if round == 0 || self.prev_loss.is_nan() {
            self.dadaquant.level()
        } else {
            self.dadaquant.observe(self.prev_loss)
        };
        let mut model_diff_history = std::mem::take(&mut self.ctx_diff_buf);
        model_diff_history.clear();
        model_diff_history.extend_from_slice(self.diff_history.as_slice());
        RoundCtx {
            round,
            num_devices: m,
            alpha: self.cfg.alpha,
            beta: self.cfg.beta,
            model_diff_sq,
            model_diff_history,
            init_loss: if self.init_loss.is_nan() { 1.0 } else { self.init_loss },
            prev_loss: if self.prev_loss.is_nan() { 1.0 } else { self.prev_loss },
            marina_sync: round == 0 || self.coin_rng.bernoulli(self.cfg.marina_p_sync),
            selected,
            dadaquant_level,
        }
    }

    /// Execute one communication round; returns its record.
    ///
    /// Equivalent to [`RoundEngine::begin_round`] →
    /// [`RoundEngine::local_device_phase`] →
    /// [`RoundEngine::finish_round`]; remote front-ends (the
    /// [`crate::protocol`] coordinator service) replace the local device
    /// phase with [`RoundEngine::stage_reset`] +
    /// [`RoundEngine::stage_remote`] injections and produce the same
    /// record bit for bit.
    pub fn run_round(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        strategy: &mut dyn SelectionStrategy,
        round: usize,
    ) -> RoundRecord {
        let ctx = self.begin_round(round, strategy);
        self.local_device_phase(problem, algo, &ctx);
        self.finish_round(problem, algo, ctx)
    }

    /// Number of devices this engine coordinates.
    pub fn num_devices(&self) -> usize {
        self.slots.len()
    }

    /// Begin round `round`: run device selection and assemble the round
    /// context every client rule will see. The context is pure data —
    /// a remote coordinator serializes it verbatim into its start-round
    /// broadcast so remote clients reconstruct it bit-exactly.
    pub fn begin_round(
        &mut self,
        round: usize,
        strategy: &mut dyn SelectionStrategy,
    ) -> RoundCtx {
        self.build_ctx(round, strategy)
    }

    /// Run the in-process device phase, parallel over the *selected
    /// cohort* (one [`WorkerScratch`] per worker thread): each selected
    /// device computes its gradient, runs the client rule, and
    /// *serializes* its upload into the slot's persistent wire buffer;
    /// payload body buffers are recycled back into the device state so
    /// steady-state rounds allocate nothing. Per-device work depends
    /// only on the device's own state and the broadcast context, never
    /// on the cohort partition, so results — theta trace and wire bytes
    /// — are bit-identical at every thread count.
    pub fn local_device_phase(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        ctx: &RoundCtx,
    ) {
        let theta = &self.theta;
        // Serial flag pass over all slots; collects the selected cohort
        // (ascending device ids, as `parallel_for_cohort` requires).
        let mut cohort = std::mem::take(&mut self.participant_buf);
        cohort.clear();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            slot.staged = false;
            slot.staged_level = None;
            slot.participated = ctx.is_selected(i);
            // Unselected devices neither compute nor consult the
            // algorithm: participation is the engine's concern, not
            // part of the `Algorithm` client contract (most client
            // rules assume a full-length gradient).
            if slot.participated {
                cohort.push(i);
            }
        }
        parallel_for_cohort(&mut self.slots, &cohort, &mut self.workers, |w, i, slot| {
            slot.loss = problem.local_grad(i, theta, &mut w.grad_full, &mut w.scratch);
            slot.state.mask.gather(&w.grad_full, &mut w.grad_gathered);
            let ClientUpload { payload, level } =
                algo.client_step(&mut slot.state, &w.grad_gathered, ctx);
            slot.staged_level = level;
            if let Some(p) = payload {
                wire::encode_into(&p, &mut slot.wire_buf);
                slot.staged = true;
                slot.state.recycle(p);
            }
        });
        self.participant_buf = cohort;
    }

    /// Reset per-round staging for a round driven by *remote* clients:
    /// marks participation from the context and clears every slot's
    /// staged upload and loss (`NaN` = not yet reported). Follow with
    /// [`RoundEngine::stage_remote`] per result, then
    /// [`RoundEngine::finish_round`]. Devices whose results never
    /// arrive are folded as skips; the metrics layer averages only the
    /// losses that did arrive.
    pub fn stage_reset(&mut self, ctx: &RoundCtx) {
        for (i, slot) in self.slots.iter_mut().enumerate() {
            slot.staged = false;
            slot.staged_level = None;
            slot.participated = ctx.is_selected(i);
            slot.loss = f64::NAN;
        }
    }

    /// Inject one remote device's round result (what its
    /// `Algorithm::client_step` produced on the client side):
    /// serialized wire payload (if it uploaded), reported level, local
    /// loss, and the device's cumulative upload/skip counters (the
    /// selection view mirrors them). Returns `false` — without
    /// panicking — if `device` is out of range or was not selected this
    /// round, so a misbehaving client cannot corrupt the round.
    pub fn stage_remote(
        &mut self,
        device: usize,
        loss: f64,
        level: Option<u8>,
        payload: Option<&[u8]>,
        counters: (u64, u64),
    ) -> bool {
        let Some(slot) = self.slots.get_mut(device) else {
            return false;
        };
        if !slot.participated {
            return false;
        }
        slot.loss = loss;
        slot.staged_level = level;
        if let Some(bytes) = payload {
            slot.wire_buf.clear();
            slot.wire_buf.extend_from_slice(bytes);
            slot.staged = true;
        }
        slot.state.uploads = counters.0;
        slot.state.skips = counters.1;
        true
    }

    /// Clear one device's staged result — the inverse of
    /// [`RoundEngine::stage_remote`]. The coordinator service calls
    /// this when the client serving `device` dies mid-round, so a
    /// half-round upload can never leak into the fold: the device
    /// returns to "not reported" (`NaN` loss, nothing staged) and
    /// either its owner rejoins and re-stages the identical result, or
    /// the round folds it as a straggler. Cumulative upload/skip
    /// counters are left as the dead client reported them (a rejoin
    /// rewrites them verbatim). Returns `false` if `device` is out of
    /// range.
    pub fn unstage(&mut self, device: usize) -> bool {
        let Some(slot) = self.slots.get_mut(device) else {
            return false;
        };
        slot.staged = false;
        slot.staged_level = None;
        slot.loss = f64::NAN;
        true
    }

    /// Record `n` stragglers detected outside the channel simulation
    /// (heartbeat-expired protocol clients) in the cumulative counter.
    pub fn note_stragglers(&mut self, n: u64) {
        self.cum_stragglers += n;
    }

    /// Complete the round from whatever is staged: transport, server
    /// fold, model update, and metrics. Consumes the context built by
    /// [`RoundEngine::begin_round`] (its history buffer is recycled).
    pub fn finish_round(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        mut ctx: RoundCtx,
    ) -> RoundRecord {
        let round = ctx.round;
        // ---- transport phase ------------------------------------------
        // Uploads stay as wire bytes end to end: the channel bills and
        // optionally drops them, the fold reads them zero-copy. The
        // channel also simulates the round's network weather: broadcast
        // time to every participant, per-device transfer times, and the
        // deadline window (DESIGN.md §Network).
        let mut participant_ids = std::mem::take(&mut self.participant_buf);
        participant_ids.clear();
        participant_ids.extend(
            self.slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.participated)
                .map(|(i, _)| i),
        );
        let model_bits = self.theta.len() as u64 * 32;
        let staged: Vec<UploadRef<'_>> = self
            .slots
            .iter()
            .filter(|s| s.staged)
            .map(|s| UploadRef {
                device: s.state.id,
                bytes: &s.wire_buf,
            })
            .collect();
        let upload_count = staged.len();
        let (delivered, stats) =
            self.channel
                .transmit(round, &participant_ids, model_bits, staged);
        self.participant_buf = participant_ids;

        // ---- server phase ---------------------------------------------
        algo.server_fold(&mut self.server, &delivered, &ctx);
        drop(delivered);
        self.prev_theta.copy_from_slice(&self.theta);
        axpy(-self.cfg.alpha, &self.server.direction, &mut self.theta);
        let diff = diff_norm2_sq(&self.theta, &self.prev_theta);
        self.diff_history.push(diff);

        // ---- metrics ----------------------------------------------------
        // `participant_buf` (ascending device order — the same order
        // the old filter pass visited) already names this round's
        // participants; reuse it rather than re-scanning the slots.
        let participant_count = self.participant_buf.len();
        // Average over the losses actually reported: in-process every
        // participant's loss is finite so this is the plain mean, while
        // a remote round leaves `NaN` in the slots of devices whose
        // clients died mid-round (`stage_reset`) and they must not
        // poison the global estimate.
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for &i in &self.participant_buf {
            let l = self.slots[i].loss;
            if l.is_finite() {
                loss_sum += l;
                loss_n += 1;
            }
        }
        let train_loss = if loss_n == 0 {
            self.prev_loss
        } else {
            loss_sum / loss_n as f64
        };
        // First *observed* loss anchors f(θ⁰): with sparse selection
        // (availability schedules) round 0 may have no participants,
        // and a NaN anchor would poison AdaQuantFL's level rule for
        // the whole run.
        if self.init_loss.is_nan() && train_loss.is_finite() {
            self.init_loss = train_loss;
        }
        self.prev_loss = train_loss;
        self.loss_history.push(train_loss);
        let levels: Vec<u8> = self
            .slots
            .iter()
            .filter_map(|s| s.staged_level)
            .collect();
        let mean_level = if levels.is_empty() {
            0.0
        } else {
            levels.iter().map(|&b| b as f64).sum::<f64>() / levels.len() as f64
        };
        self.cum_bits += stats.uplink_bits;
        self.cum_bits_down += stats.downlink_bits;
        self.cum_sim_time += stats.round_time;
        self.cum_stragglers += stats.stragglers;
        for (view, slot) in self.device_views.iter_mut().zip(&self.slots) {
            view.uploads = slot.state.uploads;
            view.skips = slot.state.skips;
            // A remote participant whose result never arrived keeps its
            // previous loss estimate (its slot holds the `NaN` sentinel).
            if slot.participated && slot.loss.is_finite() {
                view.last_loss = Some(slot.loss);
            }
        }
        let do_eval = (self.cfg.eval_every > 0 && round.is_multiple_of(self.cfg.eval_every))
            || round + 1 == self.cfg.rounds;
        let (eval_loss, accuracy, perplexity) = if do_eval {
            let ev = problem.eval(&self.theta);
            (Some(ev.loss), ev.accuracy, ev.perplexity)
        } else {
            (None, None, None)
        };
        // Hand the context's history buffer back for the next round.
        self.ctx_diff_buf = std::mem::take(&mut ctx.model_diff_history);
        RoundRecord {
            round,
            bits_up: stats.uplink_bits,
            cum_bits: self.cum_bits,
            uploads: upload_count,
            skips: participant_count.saturating_sub(upload_count),
            mean_level,
            train_loss,
            eval_loss,
            accuracy,
            perplexity,
            stragglers: stats.stragglers as usize,
            bits_down: stats.downlink_bits,
            round_time: stats.round_time,
            sim_time: self.cum_sim_time,
        }
    }

    /// Snapshot the run state (resume with [`RoundEngine::restore`]).
    /// `next_round` is the index of the first round not yet executed.
    pub fn snapshot(&self, next_round: usize) -> Checkpoint {
        let rng_state = |rng: &Xoshiro256pp| {
            let (s, gauss_cache) = rng.snapshot();
            RngState { s, gauss_cache }
        };
        Checkpoint {
            version: VERSION,
            round: next_round,
            theta: self.theta.clone(),
            prev_theta: self.prev_theta.clone(),
            direction: self.server.direction.clone(),
            device_q: self.slots.iter().map(|s| s.state.q_prev.clone()).collect(),
            device_stats: self
                .slots
                .iter()
                .map(|s| (s.state.uploads, s.state.skips, s.state.prev_err_sq))
                .collect(),
            device_rng: self.slots.iter().map(|s| rng_state(&s.state.rng)).collect(),
            coin_rng: Some(rng_state(&self.coin_rng)),
            diff_history: self.diff_history.to_vec(),
            loss_history: self.loss_history.to_vec(),
            device_last_loss: self
                .device_views
                .iter()
                .map(|v| v.last_loss.unwrap_or(f64::NAN))
                .collect(),
            cum_bits: self.cum_bits,
            bits_down: self.cum_bits_down,
            sim_time: self.cum_sim_time,
            stragglers: self.cum_stragglers,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
            // The engine knows nothing about serving; the coordinator
            // service stamps its serve-state onto the snapshot.
            serve_state: None,
        }
    }

    /// Restore a snapshot produced by [`RoundEngine::snapshot`] on an
    /// engine built with the same problem/masks/config. Returns the
    /// next round index to execute.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<usize> {
        anyhow::ensure!(
            ckpt.theta.len() == self.theta.len(),
            "checkpoint dim {} != model dim {}",
            ckpt.theta.len(),
            self.theta.len()
        );
        anyhow::ensure!(
            ckpt.device_q.len() == self.slots.len(),
            "checkpoint device count mismatch"
        );
        for (slot, q) in self.slots.iter().zip(&ckpt.device_q) {
            anyhow::ensure!(
                slot.state.q_prev.len() == q.len(),
                "device {} support mismatch",
                slot.state.id
            );
        }
        self.theta.copy_from_slice(&ckpt.theta);
        self.prev_theta.copy_from_slice(&ckpt.prev_theta);
        self.server.direction.copy_from_slice(&ckpt.direction);
        for (slot, (q, &(u, s, e))) in self
            .slots
            .iter_mut()
            .zip(ckpt.device_q.iter().zip(&ckpt.device_stats))
        {
            slot.state.q_prev.copy_from_slice(q);
            slot.state.uploads = u;
            slot.state.skips = s;
            slot.state.prev_err_sq = e;
        }
        // RNG streams (v2 checkpoints; v1 keeps fresh streams and
        // `Checkpoint::load` already warned).
        if ckpt.device_rng.len() == self.slots.len() {
            for (slot, rng) in self.slots.iter_mut().zip(&ckpt.device_rng) {
                slot.state.rng = Xoshiro256pp::from_snapshot(rng.s, rng.gauss_cache);
            }
        }
        if let Some(coin) = &ckpt.coin_rng {
            self.coin_rng = Xoshiro256pp::from_snapshot(coin.s, coin.gauss_cache);
        }
        for (i, (view, slot)) in self.device_views.iter_mut().zip(&self.slots).enumerate() {
            view.uploads = slot.state.uploads;
            view.skips = slot.state.skips;
            // v3 checkpoints carry the per-device loss estimates that
            // loss-weighted selection samples from; older versions
            // leave them unobserved.
            view.last_loss = ckpt
                .device_last_loss
                .get(i)
                .copied()
                .filter(|l| l.is_finite());
        }
        self.diff_history.assign(&ckpt.diff_history);
        self.loss_history.assign(&ckpt.loss_history);
        self.cum_bits = ckpt.cum_bits;
        self.cum_bits_down = ckpt.bits_down;
        self.cum_sim_time = ckpt.sim_time;
        self.cum_stragglers = ckpt.stragglers;
        self.init_loss = ckpt.init_loss;
        self.prev_loss = ckpt.prev_loss;
        Ok(ckpt.round)
    }
}
