//! The round engine: every piece of mutable run state plus the round
//! protocol, independent of *how* the problem/algorithm/strategy are
//! owned. The owned [`super::Session`] is a thin front-end over this
//! type.
//!
//! Since the population-virtualization redesign (DESIGN.md §Population)
//! the engine no longer keeps a `DeviceSlot` per simulated device.
//! Device identity lives in a [`PopulationSpec`] — a deterministic
//! derivation of each device's mask/sections/RNG from
//! `(seed, device_id)` — and full slot state is materialized lazily for
//! the selected cohort only, then returned to a bounded live cache
//! ([`SlotPolicy`]). Evicted devices park their persistent algorithm
//! state (`q_prev`, error norm, counters, RNG stream) in a compact
//! [`ParkedState`] and are rebuilt bit-identically on re-selection, so
//! a 1M-device run with K=1000 costs O(K + d) memory, and traces are
//! byte-identical to the eager engine (pinned by
//! `tests/prop_population.rs`).

use super::checkpoint::{AsyncMember, AsyncState, AsyncUpload, Checkpoint, RngState, VERSION};
use super::population::PopulationSpec;
use super::{AggregationMode, RunConfig, SlotPolicy, StalenessPolicy};
use crate::algorithms::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::hetero::MaskTable;
use crate::metrics::RoundRecord;
use crate::problems::{GradScratch, GradientSource};
use crate::quant::levels::DadaquantSchedule;
use crate::selection::{DeviceStats, Selection, SelectionStrategy, SelectionView};
use crate::transport::scenario::NetworkScenario;
use crate::transport::wire::{self, EncodedUpload, UploadRef};
use crate::transport::Channel;
use crate::util::pool::parallel_for_pairs;
use crate::util::ring::RecentWindow;
use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::{axpy, diff_norm2_sq};
use std::collections::BTreeMap;

/// Per-device slot: algorithm state + per-round staging, kept together
/// so one thread owns the whole cache line set. Gradient working
/// buffers live in [`WorkerScratch`] (one per worker thread, not one
/// per device), so engine memory is O(threads·d) + resident device
/// state instead of O(M·d) of scratch.
struct DeviceSlot {
    state: DeviceState,
    /// This round's serialized upload (valid when `staged`); encoded in
    /// the parallel device phase and read zero-copy by the server fold.
    /// Persists across rounds so encoding stops allocating after round 0.
    wire_buf: Vec<u8>,
    staged: bool,
    staged_level: Option<u8>,
    loss: f64,
    /// Round this slot last participated in — the LRU eviction key
    /// (ties break toward evicting lower device ids).
    last_used: usize,
}

/// The persistent algorithm state of an evicted device — everything a
/// re-materialized slot cannot rederive from the [`PopulationSpec`].
/// Scratch/staging buffers (`scratch`, `body`, `psi`, `signs`, `raw`,
/// `wire_buf`) are dropped: every client step fully overwrites them
/// before reading, so shedding them cannot change any device's result
/// (the eviction tests in `tests/prop_population.rs` pin this).
struct ParkedState {
    /// Stored reference vector `q_m` (gathered space), moved — not
    /// copied — out of the slot.
    q_prev: Vec<f32>,
    prev_err_sq: f64,
    uploads: u64,
    skips: u64,
    /// Device RNG stream snapshot (stochastic quantizers must resume
    /// mid-stream, in lockstep with the never-evicted run).
    rng: ([u64; 4], Option<f64>),
}

impl ParkedState {
    fn from_slot(slot: DeviceSlot) -> Self {
        let state = slot.state;
        Self {
            q_prev: state.q_prev,
            prev_err_sq: state.prev_err_sq,
            uploads: state.uploads,
            skips: state.skips,
            rng: state.rng.snapshot(),
        }
    }
}

/// A slot exactly as the eager engine would have built it at
/// construction time (see `PopulationSpec::fresh_state`).
fn fresh_slot(population: &PopulationSpec, id: usize) -> DeviceSlot {
    DeviceSlot {
        state: population.fresh_state(id),
        wire_buf: Vec::new(),
        staged: false,
        staged_level: None,
        loss: f64::NAN,
        last_used: 0,
    }
}

/// Rebuild an evicted device's slot: fresh derived state from the spec,
/// persistent algorithm state restored from the parked record.
fn unpark(population: &PopulationSpec, id: usize, p: ParkedState) -> DeviceSlot {
    let mut slot = fresh_slot(population, id);
    debug_assert_eq!(slot.state.q_prev.len(), p.q_prev.len());
    slot.state.q_prev = p.q_prev;
    slot.state.prev_err_sq = p.prev_err_sq;
    slot.state.uploads = p.uploads;
    slot.state.skips = p.skips;
    slot.state.rng = Xoshiro256pp::from_snapshot(p.rng.0, p.rng.1);
    slot
}

/// Gradient working set owned by one device-phase worker thread and
/// reused across the devices in its cohort chunk (and across rounds).
/// Every buffer is fully overwritten per device (`local_grad` fills the
/// whole gradient, `gather` clears before extending), so sharing scratch
/// across devices cannot change any device's result.
struct WorkerScratch {
    grad_full: Vec<f32>,
    grad_gathered: Vec<f32>,
    /// Gradient workspace (activations, deltas, softmax staging) owned
    /// by the worker so the batched `local_grad` passes allocate nothing
    /// in steady state.
    scratch: GradScratch,
}

/// One upload in flight on the buffered-async path: scheduled by a
/// dispatch, delivered by the event loop at `arrival`.
struct PendingUpload {
    /// Absolute simulated arrival time (seconds since run start).
    arrival: f64,
    /// Model version (commit count) the upload was computed against.
    version: usize,
    /// Originating device id.
    device: usize,
    /// Validated wire bytes, owned until the fold consumes them.
    bytes: Vec<u8>,
}

/// An arrived upload parked in the server buffer until the next commit.
struct BufferedUpload {
    version: usize,
    device: usize,
    bytes: Vec<u8>,
}

/// Per-dispatch accounting for one cohort member, drained by the next
/// commit (loss / level / upload-vs-skip columns of the round record).
struct MemberRecord {
    version: usize,
    device: usize,
    loss: f64,
    level: Option<u8>,
    staged: bool,
}

/// Mutable state of the buffered-async event engine
/// ([`AggregationMode::Buffered`], DESIGN.md §Async). Materialized on
/// the engine once the first buffered round runs; checkpoint v7
/// serializes it so a mid-buffer resume is byte-identical to the
/// uninterrupted run.
struct BufferedState {
    /// In-flight uploads, sorted *descending* by
    /// `(arrival, version, device)` so `pop()` yields the earliest
    /// event in O(1); `total_cmp` plus the integer tie-breaks make the
    /// order total and deterministic.
    events: Vec<PendingUpload>,
    /// Arrived uploads awaiting the next commit.
    buffer: Vec<BufferedUpload>,
    /// Dispatched-member accounting awaiting the next commit.
    pool: Vec<MemberRecord>,
    /// Next dispatch index — the selection / fault / jitter stream key,
    /// the buffered analogue of the sync round number.
    next_dispatch: usize,
    /// Committed model versions so far (= the engine's round counter).
    commits: usize,
    /// The simulated clock: the maximum of every processed arrival and
    /// broadcast floor so far; runs ahead of the engine's cumulative
    /// sim-time between commits.
    clock: f64,
    /// Cohort size of the latest dispatch — the admission estimate for
    /// the next one.
    last_cohort: usize,
    /// `RoundCtx::round` of the latest dispatch. Server folds
    /// contractually read only `round` and `marina_sync` from the
    /// context (MARINA's periodic full-sync branch), so these two are
    /// all a commit — even one resumed from a checkpoint — must carry.
    fold_round: usize,
    /// `RoundCtx::marina_sync` of the latest dispatch.
    fold_marina_sync: bool,
    /// Transport accounting accumulated per dispatch, flushed into the
    /// engine's cumulative counters at the next commit.
    pending_bits_up: u64,
    pending_bits_down: u64,
    pending_stragglers: u64,
}

impl BufferedState {
    fn new() -> Self {
        Self {
            events: Vec::new(),
            buffer: Vec::new(),
            pool: Vec::new(),
            next_dispatch: 0,
            commits: 0,
            clock: 0.0,
            last_cohort: 0,
            fold_round: 0,
            fold_marina_sync: true,
            pending_bits_up: 0,
            pending_bits_down: 0,
            pending_stragglers: 0,
        }
    }

    /// Re-establish the descending `(arrival, version, device)` order
    /// after a dispatch batch-inserts its scheduled arrivals.
    fn sort_events(&mut self) {
        self.events.sort_unstable_by(|a, b| {
            b.arrival
                .total_cmp(&a.arrival)
                .then_with(|| b.version.cmp(&a.version))
                .then_with(|| b.device.cmp(&a.device))
        });
    }

    fn to_checkpoint(&self) -> AsyncState {
        AsyncState {
            next_dispatch: self.next_dispatch,
            commits: self.commits,
            clock: self.clock,
            last_cohort: self.last_cohort,
            fold_round: self.fold_round,
            fold_marina_sync: self.fold_marina_sync,
            pending_bits_up: self.pending_bits_up,
            pending_bits_down: self.pending_bits_down,
            pending_stragglers: self.pending_stragglers,
            events: self
                .events
                .iter()
                .map(|u| AsyncUpload {
                    device: u.device,
                    version: u.version,
                    arrival: u.arrival,
                    bytes: u.bytes.clone(),
                })
                .collect(),
            buffer: self
                .buffer
                .iter()
                .map(|u| AsyncUpload {
                    device: u.device,
                    version: u.version,
                    arrival: 0.0,
                    bytes: u.bytes.clone(),
                })
                .collect(),
            pool: self
                .pool
                .iter()
                .map(|p| AsyncMember {
                    device: p.device,
                    version: p.version,
                    loss: p.loss,
                    level: p.level,
                    staged: p.staged,
                })
                .collect(),
        }
    }

    fn from_checkpoint(st: &AsyncState) -> Self {
        Self {
            events: st
                .events
                .iter()
                .map(|u| PendingUpload {
                    arrival: u.arrival,
                    version: u.version,
                    device: u.device,
                    bytes: u.bytes.clone(),
                })
                .collect(),
            buffer: st
                .buffer
                .iter()
                .map(|u| BufferedUpload {
                    version: u.version,
                    device: u.device,
                    bytes: u.bytes.clone(),
                })
                .collect(),
            pool: st
                .pool
                .iter()
                .map(|p| MemberRecord {
                    version: p.version,
                    device: p.device,
                    loss: p.loss,
                    level: p.level,
                    staged: p.staged,
                })
                .collect(),
            next_dispatch: st.next_dispatch,
            commits: st.commits,
            clock: st.clock,
            last_cohort: st.last_cohort,
            fold_round: st.fold_round,
            fold_marina_sync: st.fold_marina_sync,
            pending_bits_up: st.pending_bits_up,
            pending_bits_down: st.pending_bits_down,
            pending_stragglers: st.pending_stragglers,
        }
    }
}

/// Mutable run state + the round protocol (steps 1–5 of the module docs
/// in `crate::coordinator`). Problem, algorithm, and selection strategy
/// are passed per call so front-ends may own them however they like.
pub struct RoundEngine {
    cfg: RunConfig,
    /// Deterministic per-device derivation (mask, sections, RNG seed).
    population: PopulationSpec,
    /// Total device count `M` (cached from the population).
    m: usize,
    /// Materialized slots not currently checked out to a round, keyed
    /// by device id (`BTreeMap` so iteration is deterministic).
    live: BTreeMap<usize, DeviceSlot>,
    /// Evicted devices' persistent algorithm state ([`SlotPolicy::Lazy`]
    /// with a bounded cache).
    parked: BTreeMap<usize, ParkedState>,
    /// The in-flight round's cohort slots, ascending by device id;
    /// empty between rounds.
    round_cohort: Vec<(usize, DeviceSlot)>,
    /// Peak simultaneous fully-materialized slots (live + cohort) —
    /// the CI memory gate reads this through
    /// [`RoundEngine::peak_resident_slots`].
    max_live: usize,
    /// One gradient working set per worker thread (see [`WorkerScratch`]).
    workers: Vec<WorkerScratch>,
    server: ServerAgg,
    theta: Vec<f32>,
    prev_theta: Vec<f32>,
    channel: Channel,
    /// Recent squared model differences, most recent first.
    diff_history: RecentWindow,
    /// Recent global train losses, most recent first (selection view;
    /// persisted since checkpoint v3 so post-restore selection matches
    /// the uninterrupted run).
    loss_history: RecentWindow,
    /// Recycled buffer for `RoundCtx::model_diff_history` (the context
    /// hands it back at the end of every round — no per-round allocation).
    ctx_diff_buf: Vec<f64>,
    /// Sparse per-device statistics exposed to selection strategies;
    /// devices that never participated read as the documented default.
    stats: DeviceStats,
    init_loss: f64,
    prev_loss: f64,
    coin_rng: Xoshiro256pp,
    dadaquant: DadaquantSchedule,
    cum_bits: u64,
    /// Cumulative downlink (broadcast) bits.
    cum_bits_down: u64,
    /// Cumulative simulated wall-clock seconds.
    cum_sim_time: f64,
    /// Cumulative deadline-missing uploads.
    cum_stragglers: u64,
    /// Recycled buffer of this round's participant device ids
    /// (downlink billing + per-device link lookup in the channel).
    participant_buf: Vec<usize>,
    /// Buffered-async event state ([`AggregationMode::Buffered`]);
    /// `None` until the first buffered round runs (and always `None`
    /// on the sync path).
    buffered: Option<BufferedState>,
}

impl RoundEngine {
    /// Build the engine for `problem` with explicit per-device masks —
    /// a [`MaskTable`] or (via `Into`) a dense `Vec<Arc<CapacityMask>>`.
    pub fn new(
        problem: &dyn GradientSource,
        masks: impl Into<MaskTable>,
        cfg: RunConfig,
    ) -> Self {
        let d = problem.dim();
        let m = problem.num_devices();
        let masks = masks.into();
        assert_eq!(masks.num_devices(), m, "need one mask per device");
        for mask in masks.distinct_masks() {
            assert_eq!(mask.full_dim, d);
        }
        let theta = problem.init_theta(cfg.seed);
        // Resolve quantization sections once per *distinct* mask, from
        // the problem's layout × the run's `quant_sections` spec × the
        // mask — the population spec owns the result (devices sharing a
        // mask share the resolved `Sections`).
        let population =
            PopulationSpec::new(&problem.layout(), masks, &cfg.quant_sections, cfg.seed);
        let threads = if cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            cfg.threads
        };
        let workers = (0..threads.max(1).min(m.max(1)))
            .map(|_| WorkerScratch {
                grad_full: vec![0.0; d],
                grad_gathered: Vec::new(),
                scratch: problem.make_scratch(),
            })
            .collect();
        let mut server = ServerAgg::with_table(d, population.masks().clone());
        server.set_threads(threads);
        // Per-device links are drawn from the run seed, so the fleet —
        // like every other stochastic component — is reproducible.
        let channel =
            Channel::with_scenario(cfg.faults.clone(), cfg.network.build(m, cfg.seed));
        // Eager policy: prematerialize every slot, exactly the
        // pre-virtualization engine. Lazy: slots are built on first
        // selection.
        let mut live = BTreeMap::new();
        if cfg.slots == SlotPolicy::Eager {
            for id in 0..m {
                live.insert(id, fresh_slot(&population, id));
            }
        }
        let max_live = live.len();
        Self {
            server,
            population,
            m,
            live,
            parked: BTreeMap::new(),
            round_cohort: Vec::new(),
            max_live,
            workers,
            prev_theta: theta.clone(),
            theta,
            channel,
            diff_history: RecentWindow::new(cfg.history_depth),
            loss_history: RecentWindow::new(cfg.history_depth),
            ctx_diff_buf: Vec::with_capacity(cfg.history_depth + 1),
            stats: DeviceStats::new(),
            init_loss: f64::NAN,
            prev_loss: f64::NAN,
            coin_rng: Xoshiro256pp::stream(cfg.seed, 0xC011),
            dadaquant: DadaquantSchedule::new(
                cfg.dadaquant_b0,
                cfg.dadaquant_patience,
                cfg.dadaquant_cap,
            ),
            cfg,
            cum_bits: 0,
            cum_bits_down: 0,
            cum_sim_time: 0.0,
            cum_stragglers: 0,
            participant_buf: Vec::new(),
            buffered: None,
        }
    }

    /// Run configuration this engine was built with.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Current global model.
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Cumulative uplink bits so far (survives checkpoint restore,
    /// unlike the channel's own since-construction counter).
    pub fn total_bits(&self) -> u64 {
        self.cum_bits
    }

    /// Cumulative downlink (broadcast) bits so far.
    pub fn total_bits_down(&self) -> u64 {
        self.cum_bits_down
    }

    /// Cumulative simulated wall-clock seconds so far (0 over the
    /// ideal network).
    pub fn total_sim_time(&self) -> f64 {
        self.cum_sim_time
    }

    /// Cumulative deadline-missing uploads so far.
    pub fn total_stragglers(&self) -> u64 {
        self.cum_stragglers
    }

    /// The simulated network scenario this engine runs over.
    pub fn network(&self) -> &NetworkScenario {
        self.channel.scenario()
    }

    /// The population spec this engine derives device slots from.
    pub fn population(&self) -> &PopulationSpec {
        &self.population
    }

    /// Fully-materialized slots right now (live cache + in-flight
    /// cohort). Parked records are not counted: they hold O(support)
    /// state but no staging/scratch buffers.
    pub fn resident_slots(&self) -> usize {
        self.live.len() + self.round_cohort.len()
    }

    /// Peak simultaneous fully-materialized slots over the engine's
    /// lifetime — the CI population-bench gate asserts this stays ≤
    /// cache capacity + cohort size under [`SlotPolicy::Lazy`].
    pub fn peak_resident_slots(&self) -> usize {
        self.max_live
    }

    /// Devices currently evicted to parked (compact) state.
    pub fn parked_slots(&self) -> usize {
        self.parked.len()
    }

    /// Sparse per-device statistics (uploads/skips/last loss for every
    /// device that ever participated) — what selection strategies see.
    pub fn selection_stats(&self) -> &DeviceStats {
        &self.stats
    }

    /// Per-device upload/skip counters, densely indexed by device id.
    /// O(M) — million-device callers should prefer
    /// [`RoundEngine::selection_stats`].
    pub fn device_stats(&self) -> Vec<(u64, u64)> {
        let mut out = vec![(0, 0); self.m];
        for (id, v) in self.stats.observed() {
            out[id] = (v.uploads, v.skips);
        }
        out
    }

    fn build_ctx(&mut self, round: usize, strategy: &mut dyn SelectionStrategy) -> RoundCtx {
        let m = self.m;
        let model_diff_sq = self.diff_history.latest().unwrap_or(0.0);
        let view = SelectionView {
            round,
            num_devices: m,
            stats: &self.stats,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
            loss_history: self.loss_history.as_slice(),
        };
        let selected = match strategy.select(&view) {
            Selection::All => None,
            Selection::Devices(mut ids) => {
                // `RoundCtx::is_selected` binary-searches: sorted,
                // deduped, in-range.
                ids.retain(|&i| i < m);
                ids.sort_unstable();
                ids.dedup();
                Some(ids)
            }
        };
        let dadaquant_level = if round == 0 || self.prev_loss.is_nan() {
            self.dadaquant.level()
        } else {
            self.dadaquant.observe(self.prev_loss)
        };
        let mut model_diff_history = std::mem::take(&mut self.ctx_diff_buf);
        model_diff_history.clear();
        model_diff_history.extend_from_slice(self.diff_history.as_slice());
        RoundCtx {
            round,
            num_devices: m,
            alpha: self.cfg.alpha,
            beta: self.cfg.beta,
            model_diff_sq,
            model_diff_history,
            init_loss: if self.init_loss.is_nan() { 1.0 } else { self.init_loss },
            prev_loss: if self.prev_loss.is_nan() { 1.0 } else { self.prev_loss },
            marina_sync: round == 0 || self.coin_rng.bernoulli(self.cfg.marina_p_sync),
            selected,
            dadaquant_level,
        }
    }

    /// Execute one communication round; returns its record.
    ///
    /// Equivalent to [`RoundEngine::begin_round`] →
    /// [`RoundEngine::local_device_phase`] →
    /// [`RoundEngine::finish_round`]; remote front-ends (the
    /// [`crate::protocol`] coordinator service) replace the local device
    /// phase with [`RoundEngine::stage_reset`] +
    /// [`RoundEngine::stage_remote`] injections and produce the same
    /// record bit for bit.
    pub fn run_round(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        strategy: &mut dyn SelectionStrategy,
        round: usize,
    ) -> RoundRecord {
        let ctx = self.begin_round(round, strategy);
        self.local_device_phase(problem, algo, &ctx);
        self.finish_round(problem, algo, ctx)
    }

    /// Number of devices this engine coordinates.
    pub fn num_devices(&self) -> usize {
        self.m
    }

    /// Begin round `round`: run device selection and assemble the round
    /// context every client rule will see. The context is pure data —
    /// a remote coordinator serializes it verbatim into its start-round
    /// broadcast so remote clients reconstruct it bit-exactly.
    pub fn begin_round(
        &mut self,
        round: usize,
        strategy: &mut dyn SelectionStrategy,
    ) -> RoundCtx {
        self.build_ctx(round, strategy)
    }

    /// Check one device's slot out of the live cache — rebuilding it
    /// from parked state or the population spec if absent — reset for a
    /// new round.
    fn stage_slot(&mut self, id: usize, round: usize) {
        let mut slot = if let Some(s) = self.live.remove(&id) {
            s
        } else if let Some(p) = self.parked.remove(&id) {
            unpark(&self.population, id, p)
        } else {
            fresh_slot(&self.population, id)
        };
        slot.staged = false;
        slot.staged_level = None;
        // `NaN` = not yet reported; the in-process device phase
        // overwrites it, the remote path leaves it for devices whose
        // results never arrive (folded as stragglers).
        slot.loss = f64::NAN;
        slot.last_used = round;
        self.round_cohort.push((id, slot));
    }

    /// Materialize the round's cohort (ascending device ids — the
    /// normalized `ctx.selected` order) into `round_cohort`. Unselected
    /// devices are never touched: their slots (or parked records) stay
    /// exactly as the previous round left them, which is what makes
    /// lazy materialization trace-equivalent to the eager engine.
    fn take_cohort_slots(&mut self, ctx: &RoundCtx) {
        debug_assert!(
            self.round_cohort.is_empty(),
            "round already in flight (finish_round not called?)"
        );
        match &ctx.selected {
            Some(ids) => {
                for &id in ids {
                    self.stage_slot(id, ctx.round);
                }
            }
            None => {
                for id in 0..self.m {
                    self.stage_slot(id, ctx.round);
                }
            }
        }
        self.max_live = self.max_live.max(self.live.len() + self.round_cohort.len());
    }

    /// Run the in-process device phase, parallel over the *selected
    /// cohort* (one [`WorkerScratch`] per worker thread): each selected
    /// device computes its gradient, runs the client rule, and
    /// *serializes* its upload into the slot's persistent wire buffer;
    /// payload body buffers are recycled back into the device state so
    /// steady-state rounds allocate nothing. Per-device work depends
    /// only on the device's own state and the broadcast context, never
    /// on the cohort partition, so results — theta trace and wire bytes
    /// — are bit-identical at every thread count.
    pub fn local_device_phase(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        ctx: &RoundCtx,
    ) {
        self.take_cohort_slots(ctx);
        let theta = &self.theta;
        parallel_for_pairs(&mut self.round_cohort, &mut self.workers, |w, i, slot| {
            slot.loss = problem.local_grad(i, theta, &mut w.grad_full, &mut w.scratch);
            slot.state.mask.gather(&w.grad_full, &mut w.grad_gathered);
            let ClientUpload { payload, level } =
                algo.client_step(&mut slot.state, &w.grad_gathered, ctx);
            slot.staged_level = level;
            if let Some(p) = payload {
                wire::encode_into(&p, &mut slot.wire_buf);
                slot.staged = true;
                slot.state.recycle(p);
            }
        });
    }

    /// Materialize the cohort for a round driven by *remote* clients:
    /// every selected device's slot is checked out with nothing staged
    /// and a `NaN` (= not yet reported) loss. Follow with
    /// [`RoundEngine::stage_remote`] per result, then
    /// [`RoundEngine::finish_round`]. Devices whose results never
    /// arrive are folded as skips; the metrics layer averages only the
    /// losses that did arrive.
    pub fn stage_reset(&mut self, ctx: &RoundCtx) {
        self.take_cohort_slots(ctx);
    }

    /// Inject one remote device's round result (what its
    /// `Algorithm::client_step` produced on the client side):
    /// serialized wire payload (if it uploaded), reported level, local
    /// loss, and the device's cumulative upload/skip counters (the
    /// selection view mirrors them). Returns `false` — without
    /// panicking — if `device` is out of range or was not selected this
    /// round, so a misbehaving client cannot corrupt the round.
    pub fn stage_remote(
        &mut self,
        device: usize,
        loss: f64,
        level: Option<u8>,
        payload: Option<&[u8]>,
        counters: (u64, u64),
    ) -> bool {
        let Ok(pos) = self
            .round_cohort
            .binary_search_by_key(&device, |&(id, _)| id)
        else {
            return false;
        };
        let slot = &mut self.round_cohort[pos].1;
        slot.loss = loss;
        slot.staged_level = level;
        if let Some(bytes) = payload {
            slot.wire_buf.clear();
            slot.wire_buf.extend_from_slice(bytes);
            slot.staged = true;
        }
        slot.state.uploads = counters.0;
        slot.state.skips = counters.1;
        true
    }

    /// Clear one device's staged result — the inverse of
    /// [`RoundEngine::stage_remote`]. The coordinator service calls
    /// this when the client serving `device` dies mid-round, so a
    /// half-round upload can never leak into the fold: the device
    /// returns to "not reported" (`NaN` loss, nothing staged) and
    /// either its owner rejoins and re-stages the identical result, or
    /// the round folds it as a straggler. Cumulative upload/skip
    /// counters are left as the dead client reported them (a rejoin
    /// rewrites them verbatim). Returns `false` if `device` is out of
    /// range or not part of the in-flight cohort.
    pub fn unstage(&mut self, device: usize) -> bool {
        let Ok(pos) = self
            .round_cohort
            .binary_search_by_key(&device, |&(id, _)| id)
        else {
            return false;
        };
        let slot = &mut self.round_cohort[pos].1;
        slot.staged = false;
        slot.staged_level = None;
        slot.loss = f64::NAN;
        true
    }

    /// Record `n` stragglers detected outside the channel simulation
    /// (heartbeat-expired protocol clients) in the cumulative counter.
    pub fn note_stragglers(&mut self, n: u64) {
        self.cum_stragglers += n;
    }

    /// Complete the round from whatever is staged: transport, server
    /// fold, model update, metrics, and slot-cache maintenance (cohort
    /// slots return to the live cache; the LRU overflow is parked).
    /// Consumes the context built by [`RoundEngine::begin_round`] (its
    /// history buffer is recycled).
    pub fn finish_round(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        mut ctx: RoundCtx,
    ) -> RoundRecord {
        let round = ctx.round;
        // ---- transport phase ------------------------------------------
        // Uploads stay as wire bytes end to end: the channel bills and
        // optionally drops them, the fold reads them zero-copy. The
        // channel also simulates the round's network weather: broadcast
        // time to every participant, per-device transfer times, and the
        // deadline window (DESIGN.md §Network).
        let mut participant_ids = std::mem::take(&mut self.participant_buf);
        participant_ids.clear();
        participant_ids.extend(self.round_cohort.iter().map(|&(id, _)| id));
        let model_bits = self.theta.len() as u64 * 32;
        let staged: Vec<UploadRef<'_>> = self
            .round_cohort
            .iter()
            .filter(|(_, s)| s.staged)
            .map(|(id, s)| UploadRef {
                device: *id,
                bytes: &s.wire_buf,
            })
            .collect();
        let upload_count = staged.len();
        let (delivered, stats) =
            self.channel
                .transmit(round, &participant_ids, model_bits, staged);
        self.participant_buf = participant_ids;

        // ---- server phase ---------------------------------------------
        algo.server_fold(&mut self.server, &delivered, &ctx);
        drop(delivered);
        self.prev_theta.copy_from_slice(&self.theta);
        axpy(-self.cfg.alpha, &self.server.direction, &mut self.theta);
        let diff = diff_norm2_sq(&self.theta, &self.prev_theta);
        self.diff_history.push(diff);

        // ---- metrics ----------------------------------------------------
        let participant_count = self.round_cohort.len();
        // Average over the losses actually reported: in-process every
        // participant's loss is finite so this is the plain mean, while
        // a remote round leaves `NaN` in the slots of devices whose
        // clients died mid-round (`stage_reset`) and they must not
        // poison the global estimate.
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for (_, slot) in &self.round_cohort {
            if slot.loss.is_finite() {
                loss_sum += slot.loss;
                loss_n += 1;
            }
        }
        let train_loss = if loss_n == 0 {
            self.prev_loss
        } else {
            loss_sum / loss_n as f64
        };
        // First *observed* loss anchors f(θ⁰): with sparse selection
        // (availability schedules) round 0 may have no participants,
        // and a NaN anchor would poison AdaQuantFL's level rule for
        // the whole run.
        if self.init_loss.is_nan() && train_loss.is_finite() {
            self.init_loss = train_loss;
        }
        self.prev_loss = train_loss;
        self.loss_history.push(train_loss);
        let mut level_sum = 0u64;
        let mut level_n = 0usize;
        for (_, slot) in &self.round_cohort {
            if let Some(l) = slot.staged_level {
                level_sum += l as u64;
                level_n += 1;
            }
        }
        let mean_level = if level_n == 0 {
            0.0
        } else {
            level_sum as f64 / level_n as f64
        };
        self.cum_bits += stats.uplink_bits;
        self.cum_bits_down += stats.downlink_bits;
        // Record the round's wall-clock cost as the *difference of
        // cumulative times* — the same arithmetic the buffered engine
        // uses between commits, so the degenerate buffered
        // configuration reproduces this column bit for bit.
        let prev_sim_time = self.cum_sim_time;
        self.cum_sim_time += stats.round_time;
        let round_time = self.cum_sim_time - prev_sim_time;
        self.cum_stragglers += stats.stragglers;
        // Sparse statistics update: only cohort members can have changed
        // counters or observed a loss this round, so touching just them
        // is exactly the old dense per-device pass.
        for (id, slot) in &self.round_cohort {
            let v = self.stats.entry(*id);
            v.uploads = slot.state.uploads;
            v.skips = slot.state.skips;
            // A remote participant whose result never arrived keeps its
            // previous loss estimate (its slot holds the `NaN` sentinel).
            if slot.loss.is_finite() {
                v.last_loss = Some(slot.loss);
            }
        }
        let do_eval = (self.cfg.eval_every > 0 && round.is_multiple_of(self.cfg.eval_every))
            || round + 1 == self.cfg.rounds;
        let (eval_loss, accuracy, perplexity) = if do_eval {
            let ev = problem.eval(&self.theta);
            (Some(ev.loss), ev.accuracy, ev.perplexity)
        } else {
            (None, None, None)
        };
        self.return_cohort();
        // Hand the context's history buffer back for the next round.
        self.ctx_diff_buf = std::mem::take(&mut ctx.model_diff_history);
        RoundRecord {
            round,
            bits_up: stats.uplink_bits,
            cum_bits: self.cum_bits,
            uploads: upload_count,
            skips: participant_count.saturating_sub(upload_count),
            mean_level,
            train_loss,
            eval_loss,
            accuracy,
            perplexity,
            stragglers: stats.stragglers as usize,
            bits_down: stats.downlink_bits,
            round_time,
            sim_time: self.cum_sim_time,
            mean_staleness: 0.0,
            max_staleness: 0,
            inflight: 0,
        }
    }

    /// Return the in-flight cohort's slots to the live cache; under a
    /// bounded lazy policy the least-recently-used overflow (ties
    /// toward lower ids) is parked to compact state.
    fn return_cohort(&mut self) {
        for (id, slot) in self.round_cohort.drain(..) {
            self.live.insert(id, slot);
        }
        if let SlotPolicy::Lazy { cache } = self.cfg.slots {
            if cache > 0 && self.live.len() > cache {
                let excess = self.live.len() - cache;
                let mut order: Vec<(usize, usize)> = self
                    .live
                    .iter()
                    .map(|(&id, s)| (s.last_used, id))
                    .collect();
                order.sort_unstable();
                for &(_, id) in order.iter().take(excess) {
                    let slot = self.live.remove(&id).expect("listed from live");
                    self.parked.insert(id, ParkedState::from_slot(slot));
                }
            }
        }
    }

    /// Execute one buffered-async *commit* (DESIGN.md §Async): drive
    /// the event loop — dispatching cohorts and delivering uploads at
    /// their link-derived arrival times — until `m` uploads have
    /// buffered (or the queue runs dry), then fold the buffer with
    /// staleness weights and commit model version `commit`. The
    /// returned record is keyed by commit: `round_time` is the
    /// simulated time between commits, `inflight` counts uploads still
    /// traveling when the version committed.
    ///
    /// Requires [`RunConfig::aggregation`] to be
    /// [`AggregationMode::Buffered`]; commits must be driven in order,
    /// exactly like [`RoundEngine::run_round`]'s rounds.
    pub fn run_buffered_round(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        strategy: &mut dyn SelectionStrategy,
        commit: usize,
    ) -> RoundRecord {
        let AggregationMode::Buffered {
            m,
            staleness,
            max_inflight,
        } = self.cfg.aggregation.clone()
        else {
            panic!("run_buffered_round requires AggregationMode::Buffered");
        };
        let mut st = self.buffered.take().unwrap_or_else(BufferedState::new);
        debug_assert_eq!(st.commits, commit, "buffered commits must be driven in order");
        let record = loop {
            // A full buffer commits before anything else — in
            // particular before the next dispatch, so selection at
            // dispatch d always observes every commit whose arrivals
            // the clock has already passed.
            if st.buffer.len() >= m {
                break self.buffered_commit(problem, algo, staleness, &mut st);
            }
            if st.events.is_empty() {
                if !st.buffer.is_empty() || !st.pool.is_empty() {
                    // The queue ran dry mid-buffer: flush what arrived
                    // (the buffered analogue of the sync engine closing
                    // a fault-thinned round on its survivors).
                    break self.buffered_commit(problem, algo, staleness, &mut st);
                }
                // Idle (cold start or post-commit drain): dispatch.
                self.buffered_dispatch(problem, algo, strategy, &mut st);
                if st.events.is_empty() && st.pool.is_empty() {
                    // An empty cohort — commit the empty round, exactly
                    // as the sync engine records an empty selection.
                    break self.buffered_commit(problem, algo, staleness, &mut st);
                }
                continue;
            }
            // Overlap: admit the next cohort while uploads are still in
            // flight when the bound allows, at most one dispatch per
            // delivered arrival — dispatching can never outrun the
            // network, so the queue and member pool stay bounded.
            if st.events.len() + st.last_cohort.max(1) <= max_inflight {
                self.buffered_dispatch(problem, algo, strategy, &mut st);
            }
            let ev = st.events.pop().expect("event queue checked non-empty");
            st.clock = st.clock.max(ev.arrival);
            st.buffer.push(BufferedUpload {
                version: ev.version,
                device: ev.device,
                bytes: ev.bytes,
            });
        };
        self.buffered = Some(st);
        record
    }

    /// Dispatch one cohort on the buffered path: select, run the local
    /// device phase against the current model, hand the staged uploads
    /// to the link layer, and schedule their arrival events. The clock
    /// advances to the broadcast completion (no upload can start before
    /// the model reaches its device); slots return to the cache right
    /// away, so devices with uploads still in flight are re-selected
    /// deterministically by later dispatches.
    fn buffered_dispatch(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        strategy: &mut dyn SelectionStrategy,
        st: &mut BufferedState,
    ) {
        let dispatch = st.next_dispatch;
        let mut ctx = self.build_ctx(dispatch, strategy);
        st.fold_round = ctx.round;
        st.fold_marina_sync = ctx.marina_sync;
        self.local_device_phase(problem, algo, &ctx);
        self.ctx_diff_buf = std::mem::take(&mut ctx.model_diff_history);
        let mut participant_ids = std::mem::take(&mut self.participant_buf);
        participant_ids.clear();
        participant_ids.extend(self.round_cohort.iter().map(|&(id, _)| id));
        let model_bits = self.theta.len() as u64 * 32;
        let mut uploads = Vec::new();
        for (id, slot) in &mut self.round_cohort {
            st.pool.push(MemberRecord {
                version: st.commits,
                device: *id,
                loss: slot.loss,
                level: slot.staged_level,
                staged: slot.staged,
            });
            if slot.staged {
                // Move the wire bytes out — the event owns them until
                // the fold; the slot's buffer regrows on next upload.
                uploads.push(EncodedUpload {
                    device: *id,
                    bytes: std::mem::take(&mut slot.wire_buf),
                });
            }
        }
        let (events, stats) =
            self.channel
                .transmit_async(dispatch, &participant_ids, model_bits, uploads);
        self.participant_buf = participant_ids;
        st.pending_bits_up += stats.uplink_bits;
        st.pending_bits_down += stats.downlink_bits;
        st.pending_stragglers += stats.stragglers;
        let t0 = st.clock;
        for e in events {
            st.events.push(PendingUpload {
                arrival: t0 + e.offset,
                version: st.commits,
                device: e.device,
                bytes: e.bytes,
            });
        }
        st.sort_events();
        // Broadcast floor: even if every upload is dropped the clock
        // cannot pass under the model transfer (`stats.round_time` is
        // the broadcast time on the async path).
        st.clock = st.clock.max(t0 + stats.round_time);
        // Cohort bookkeeping runs at dispatch so later overlapping
        // dispatches observe it; in the degenerate sync-equivalent
        // schedule this is exactly the state the sync engine exposes
        // to round d+1.
        for (id, slot) in &self.round_cohort {
            let v = self.stats.entry(*id);
            v.uploads = slot.state.uploads;
            v.skips = slot.state.skips;
            if slot.loss.is_finite() {
                v.last_loss = Some(slot.loss);
            }
        }
        st.last_cohort = self.round_cohort.len();
        self.return_cohort();
        st.next_dispatch += 1;
    }

    /// Fold the arrived buffer into model version `st.commits`, apply
    /// the staleness weights, advance the model, and emit the
    /// commit-keyed record. Uploads fold in `(version, device)` order —
    /// the dispatch order — so the shard fold accumulates in the same
    /// sequence the sync engine would.
    fn buffered_commit(
        &mut self,
        problem: &dyn GradientSource,
        algo: &dyn Algorithm,
        staleness: StalenessPolicy,
        st: &mut BufferedState,
    ) -> RoundRecord {
        let commit = st.commits;
        st.buffer.sort_unstable_by_key(|u| (u.version, u.device));
        let staged: Vec<UploadRef<'_>> = st
            .buffer
            .iter()
            .map(|u| UploadRef {
                device: u.device,
                bytes: &u.bytes,
            })
            .collect();
        let mut staleness_sum = 0usize;
        let mut max_staleness = 0usize;
        let mut weights = Vec::with_capacity(staged.len());
        for u in &st.buffer {
            let s = commit - u.version;
            staleness_sum += s;
            max_staleness = max_staleness.max(s);
            weights.push(staleness.weight(s));
        }
        let mean_staleness = if st.buffer.is_empty() {
            0.0
        } else {
            staleness_sum as f64 / st.buffer.len() as f64
        };
        // Stage the weights only when they can change the fold: an
        // all-ones weight vector must leave the accumulate path — and
        // its float arithmetic — bit-identical to the unweighted sync
        // fold (and `fold_average`'s empty early-return must not leave
        // weights staged for a later call).
        let one = 1.0f32.to_bits();
        if !staged.is_empty() && weights.iter().any(|w| w.to_bits() != one) {
            self.server.stage_upload_weights(weights);
        }
        let mut ctx = self.fold_ctx(st.fold_round, st.fold_marina_sync);
        algo.server_fold(&mut self.server, &staged, &ctx);
        drop(staged);
        self.ctx_diff_buf = std::mem::take(&mut ctx.model_diff_history);
        self.prev_theta.copy_from_slice(&self.theta);
        axpy(-self.cfg.alpha, &self.server.direction, &mut self.theta);
        let diff = diff_norm2_sq(&self.theta, &self.prev_theta);
        self.diff_history.push(diff);

        // ---- metrics: drain the member pool ---------------------------
        st.pool.sort_unstable_by_key(|p| (p.version, p.device));
        let participant_count = st.pool.len();
        let mut upload_count = 0usize;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut level_sum = 0u64;
        let mut level_n = 0usize;
        for p in &st.pool {
            if p.staged {
                upload_count += 1;
            }
            if p.loss.is_finite() {
                loss_sum += p.loss;
                loss_n += 1;
            }
            if let Some(l) = p.level {
                level_sum += l as u64;
                level_n += 1;
            }
        }
        let train_loss = if loss_n == 0 {
            self.prev_loss
        } else {
            loss_sum / loss_n as f64
        };
        if self.init_loss.is_nan() && train_loss.is_finite() {
            self.init_loss = train_loss;
        }
        self.prev_loss = train_loss;
        self.loss_history.push(train_loss);
        let mean_level = if level_n == 0 {
            0.0
        } else {
            level_sum as f64 / level_n as f64
        };
        let bits_up = std::mem::take(&mut st.pending_bits_up);
        let bits_down = std::mem::take(&mut st.pending_bits_down);
        let stragglers = std::mem::take(&mut st.pending_stragglers);
        self.cum_bits += bits_up;
        self.cum_bits_down += bits_down;
        self.cum_stragglers += stragglers;
        // The commit's wall-clock cost is the clock advance since the
        // previous commit — a difference of cumulative times, the same
        // arithmetic the sync path records.
        let round_time = st.clock - self.cum_sim_time;
        self.cum_sim_time = st.clock;
        let do_eval = (self.cfg.eval_every > 0 && commit.is_multiple_of(self.cfg.eval_every))
            || commit + 1 == self.cfg.rounds;
        let (eval_loss, accuracy, perplexity) = if do_eval {
            let ev = problem.eval(&self.theta);
            (Some(ev.loss), ev.accuracy, ev.perplexity)
        } else {
            (None, None, None)
        };
        st.buffer.clear();
        st.pool.clear();
        st.commits += 1;
        RoundRecord {
            round: commit,
            bits_up,
            cum_bits: self.cum_bits,
            uploads: upload_count,
            skips: participant_count.saturating_sub(upload_count),
            mean_level,
            train_loss,
            eval_loss,
            accuracy,
            perplexity,
            stragglers: stragglers as usize,
            bits_down,
            round_time,
            sim_time: self.cum_sim_time,
            mean_staleness,
            max_staleness,
            inflight: st.events.len(),
        }
    }

    /// Assemble the server-fold context for a buffered commit. Server
    /// folds contractually read only `round` and `marina_sync` from
    /// their context (MARINA's periodic full-sync branch; the
    /// degenerate-equivalence gate in `tests/prop_async.rs` would trip
    /// on any new dependency) — those two come from the dispatch that
    /// most recently ran, everything else is engine-current.
    fn fold_ctx(&mut self, round: usize, marina_sync: bool) -> RoundCtx {
        let mut model_diff_history = std::mem::take(&mut self.ctx_diff_buf);
        model_diff_history.clear();
        model_diff_history.extend_from_slice(self.diff_history.as_slice());
        RoundCtx {
            round,
            num_devices: self.m,
            alpha: self.cfg.alpha,
            beta: self.cfg.beta,
            model_diff_sq: self.diff_history.latest().unwrap_or(0.0),
            model_diff_history,
            init_loss: if self.init_loss.is_nan() { 1.0 } else { self.init_loss },
            prev_loss: if self.prev_loss.is_nan() { 1.0 } else { self.prev_loss },
            marina_sync,
            selected: None,
            dadaquant_level: self.dadaquant.level(),
        }
    }

    /// Snapshot the run state (resume with [`RoundEngine::restore`]).
    /// `next_round` is the index of the first round not yet executed.
    /// Since checkpoint v6 the snapshot is *sparse*: it records state
    /// for the devices this run ever materialized (live + parked), not
    /// the whole population — an eager engine therefore still writes
    /// every device, exactly the old dense format.
    pub fn snapshot(&self, next_round: usize) -> Checkpoint {
        debug_assert!(
            self.round_cohort.is_empty(),
            "snapshot mid-round (finish_round not called?)"
        );
        let rng_state = |rng: &Xoshiro256pp| {
            let (s, gauss_cache) = rng.snapshot();
            RngState { s, gauss_cache }
        };
        let mut device_ids: Vec<usize> =
            self.live.keys().chain(self.parked.keys()).copied().collect();
        device_ids.sort_unstable();
        let n = device_ids.len();
        let mut device_q = Vec::with_capacity(n);
        let mut device_stats = Vec::with_capacity(n);
        let mut device_rng = Vec::with_capacity(n);
        let mut device_last_loss = Vec::with_capacity(n);
        for &id in &device_ids {
            if let Some(slot) = self.live.get(&id) {
                device_q.push(slot.state.q_prev.clone());
                device_stats.push((slot.state.uploads, slot.state.skips, slot.state.prev_err_sq));
                device_rng.push(rng_state(&slot.state.rng));
            } else {
                let p = &self.parked[&id];
                device_q.push(p.q_prev.clone());
                device_stats.push((p.uploads, p.skips, p.prev_err_sq));
                device_rng.push(RngState {
                    s: p.rng.0,
                    gauss_cache: p.rng.1,
                });
            }
            device_last_loss.push(self.stats.get(id).last_loss.unwrap_or(f64::NAN));
        }
        Checkpoint {
            version: VERSION,
            round: next_round,
            population: self.m,
            device_ids,
            theta: self.theta.clone(),
            prev_theta: self.prev_theta.clone(),
            direction: self.server.direction.clone(),
            device_q,
            device_stats,
            device_rng,
            coin_rng: Some(rng_state(&self.coin_rng)),
            diff_history: self.diff_history.to_vec(),
            loss_history: self.loss_history.to_vec(),
            device_last_loss,
            cum_bits: self.cum_bits,
            bits_down: self.cum_bits_down,
            sim_time: self.cum_sim_time,
            stragglers: self.cum_stragglers,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
            // The engine knows nothing about serving; the coordinator
            // service stamps its serve-state onto the snapshot.
            serve_state: None,
            async_state: self.buffered.as_ref().map(BufferedState::to_checkpoint),
        }
    }

    /// Restore a snapshot produced by [`RoundEngine::snapshot`] on an
    /// engine built with the same problem/masks/config. Returns the
    /// next round index to execute. v1–v5 checkpoints (dense per-device
    /// state) migrate into the sparse store: their tracked set is the
    /// whole population.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<usize> {
        anyhow::ensure!(
            ckpt.theta.len() == self.theta.len(),
            "checkpoint dim {} != model dim {}",
            ckpt.theta.len(),
            self.theta.len()
        );
        anyhow::ensure!(
            ckpt.population == self.m,
            "checkpoint device count mismatch"
        );
        anyhow::ensure!(
            ckpt.device_ids.len() == ckpt.device_q.len()
                && ckpt.device_ids.len() == ckpt.device_stats.len(),
            "checkpoint tracked-device sections disagree"
        );
        for (&id, q) in ckpt.device_ids.iter().zip(&ckpt.device_q) {
            anyhow::ensure!(id < self.m, "checkpoint device {id} out of range");
            anyhow::ensure!(
                self.population.mask_of(id).support() == q.len(),
                "device {id} support mismatch"
            );
        }
        self.theta.copy_from_slice(&ckpt.theta);
        self.prev_theta.copy_from_slice(&ckpt.prev_theta);
        self.server.direction.copy_from_slice(&ckpt.direction);
        self.live.clear();
        self.parked.clear();
        self.round_cohort.clear();
        self.stats.clear();
        // RNG streams are present since v2; a v1 checkpoint resumes
        // with fresh id-keyed streams (`Checkpoint::load` already
        // warned).
        let with_rng = ckpt.device_rng.len() == ckpt.device_q.len();
        for (idx, &id) in ckpt.device_ids.iter().enumerate() {
            let (u, s, e) = ckpt.device_stats[idx];
            let rng = if with_rng {
                (ckpt.device_rng[idx].s, ckpt.device_rng[idx].gauss_cache)
            } else {
                DeviceState::rng_stream(id, self.population.seed()).snapshot()
            };
            self.parked.insert(
                id,
                ParkedState {
                    q_prev: ckpt.device_q[idx].clone(),
                    prev_err_sq: e,
                    uploads: u,
                    skips: s,
                    rng,
                },
            );
            let v = self.stats.entry(id);
            v.uploads = u;
            v.skips = s;
            // v3 checkpoints carry the per-device loss estimates that
            // loss-weighted selection samples from; older versions
            // leave them unobserved.
            v.last_loss = ckpt
                .device_last_loss
                .get(idx)
                .copied()
                .filter(|l| l.is_finite());
        }
        // Eager engines materialize the whole population up front;
        // restored (tracked) devices unpark, the rest are fresh.
        if self.cfg.slots == SlotPolicy::Eager {
            for id in 0..self.m {
                let slot = match self.parked.remove(&id) {
                    Some(p) => unpark(&self.population, id, p),
                    None => fresh_slot(&self.population, id),
                };
                self.live.insert(id, slot);
            }
        }
        self.max_live = self.max_live.max(self.live.len());
        if let Some(coin) = &ckpt.coin_rng {
            self.coin_rng = Xoshiro256pp::from_snapshot(coin.s, coin.gauss_cache);
        }
        self.diff_history.assign(&ckpt.diff_history);
        self.loss_history.assign(&ckpt.loss_history);
        self.cum_bits = ckpt.cum_bits;
        self.cum_bits_down = ckpt.bits_down;
        self.cum_sim_time = ckpt.sim_time;
        self.cum_stragglers = ckpt.stragglers;
        self.init_loss = ckpt.init_loss;
        self.prev_loss = ckpt.prev_loss;
        // Buffered-async event state (checkpoint v7): in-flight
        // uploads, the partial buffer, and the member pool resume
        // exactly where the snapshot left them; older checkpoints (and
        // sync runs) carry none.
        self.buffered = ckpt.async_state.as_ref().map(BufferedState::from_checkpoint);
        Ok(ckpt.round)
    }
}
