//! The owned run API: [`SessionBuilder`] composes an `Arc`-owned
//! problem/algorithm with a pluggable [`SelectionStrategy`] and any
//! number of [`RoundObserver`] metric sinks into a [`Session`].
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use aquila::coordinator::{RunConfig, Session};
//! # use aquila::algorithms::aquila::Aquila;
//! # use aquila::problems::quadratic::QuadraticProblem;
//! # use aquila::selection::SelectionSpec;
//! let problem = Arc::new(QuadraticProblem::new(32, 8, 0.5, 2.0, 0.5, 1));
//! let algo = Arc::new(Aquila::new(0.25));
//! let trace = Session::builder(problem, algo)
//!     .config(RunConfig { rounds: 50, ..RunConfig::default() })
//!     .selection_spec(SelectionSpec::RandomK(3))
//!     .dataset("quad")
//!     .split("iid")
//!     .build()
//!     .run();
//! ```

use super::checkpoint::Checkpoint;
use super::engine::RoundEngine;
use super::RunConfig;
use crate::algorithms::Algorithm;
use crate::hetero::{CapacityMask, MaskTable};
use crate::metrics::observer::{RoundObserver, RunMeta};
use crate::metrics::{RoundRecord, RunTrace};
use crate::problems::GradientSource;
use crate::selection::{SelectionSpec, SelectionStrategy};
use std::path::PathBuf;
use std::sync::Arc;

/// Builder for [`Session`]. Construct via [`Session::builder`].
pub struct SessionBuilder {
    problem: Arc<dyn GradientSource>,
    algo: Arc<dyn Algorithm>,
    cfg: RunConfig,
    masks: Option<MaskTable>,
    strategy: Option<Box<dyn SelectionStrategy>>,
    spec: Option<SelectionSpec>,
    observers: Vec<Box<dyn RoundObserver>>,
    dataset: String,
    split: String,
}

impl SessionBuilder {
    /// Builder with default config, full capacity, full participation,
    /// and no observers.
    pub fn new(problem: Arc<dyn GradientSource>, algo: Arc<dyn Algorithm>) -> Self {
        Self {
            problem,
            algo,
            cfg: RunConfig::default(),
            masks: None,
            strategy: None,
            spec: None,
            observers: Vec::new(),
            dataset: "unnamed".to_string(),
            split: "default".to_string(),
        }
    }

    /// Runtime configuration (learning rate, rounds, seed, ...).
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Explicit per-device capacity masks (heterogeneous runs); default
    /// is full capacity everywhere.
    pub fn masks(mut self, masks: Vec<Arc<CapacityMask>>) -> Self {
        self.masks = Some(MaskTable::from(masks));
        self
    }

    /// Capacity masks as a compact [`MaskTable`] — the only sensible
    /// spelling for million-device populations, where a dense mask
    /// vector would itself be O(M).
    pub fn mask_table(mut self, masks: MaskTable) -> Self {
        self.masks = Some(masks);
        self
    }

    /// Inject a selection strategy instance. Takes precedence over
    /// [`SessionBuilder::selection_spec`].
    pub fn selection(mut self, strategy: Box<dyn SelectionStrategy>) -> Self {
        self.strategy = Some(strategy);
        self
    }

    /// Build the strategy from a config-parseable spec at
    /// [`SessionBuilder::build`] time (needs the device count + seed).
    pub fn selection_spec(mut self, spec: SelectionSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Attach a streaming metrics sink; may be called repeatedly.
    pub fn observer(mut self, obs: Box<dyn RoundObserver>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Dataset label recorded in traces.
    pub fn dataset(mut self, name: &str) -> Self {
        self.dataset = name.to_string();
        self
    }

    /// Split label recorded in traces.
    pub fn split(mut self, name: &str) -> Self {
        self.split = name.to_string();
        self
    }

    /// Assemble the session. Strategy precedence: explicit instance >
    /// spec > deprecated `RunConfig::sample_k` (kept so old configs
    /// keep working) > full participation.
    pub fn build(self) -> Session {
        let m = self.problem.num_devices();
        let d = self.problem.dim();
        let masks = self.masks.unwrap_or_else(|| MaskTable::uniform_full(d, m));
        let strategy: Box<dyn SelectionStrategy> = match (self.strategy, self.spec) {
            (Some(s), _) => s,
            (None, Some(spec)) => spec.build(m, self.cfg.seed),
            (None, None) => super::strategy_from_cfg(&self.cfg),
        };
        let engine = RoundEngine::new(self.problem.as_ref(), masks, self.cfg);
        Session {
            problem: self.problem,
            algo: self.algo,
            strategy,
            observers: self.observers,
            engine,
            dataset: self.dataset,
            split: self.split,
            checkpoint: None,
        }
    }
}

/// An owned federated run: problem + algorithm + selection strategy +
/// observers + mutable round state. (The lifetime-bound `Coordinator`
/// front-end it replaced has been removed.)
pub struct Session {
    problem: Arc<dyn GradientSource>,
    algo: Arc<dyn Algorithm>,
    strategy: Box<dyn SelectionStrategy>,
    observers: Vec<Box<dyn RoundObserver>>,
    engine: RoundEngine,
    dataset: String,
    split: String,
    checkpoint: Option<(PathBuf, usize)>,
}

/// Simultaneous borrows of a [`Session`]'s components, so a front-end
/// that owns a session (the [`crate::protocol`] coordinator service)
/// can drive the engine with the problem/algorithm/strategy/observers
/// alongside it.
pub(crate) struct SessionParts<'a> {
    pub engine: &'a mut RoundEngine,
    pub problem: &'a dyn GradientSource,
    pub algo: &'a dyn Algorithm,
    pub strategy: &'a mut dyn SelectionStrategy,
    pub observers: &'a mut Vec<Box<dyn RoundObserver>>,
}

impl Session {
    /// Start building a session.
    pub fn builder(problem: Arc<dyn GradientSource>, algo: Arc<dyn Algorithm>) -> SessionBuilder {
        SessionBuilder::new(problem, algo)
    }

    /// Borrow every component at once (disjoint fields, one call).
    pub(crate) fn parts(&mut self) -> SessionParts<'_> {
        SessionParts {
            engine: &mut self.engine,
            problem: self.problem.as_ref(),
            algo: self.algo.as_ref(),
            strategy: self.strategy.as_mut(),
            observers: &mut self.observers,
        }
    }

    /// The run metadata observers receive at run start.
    pub fn meta(&self) -> RunMeta {
        RunMeta {
            algorithm: self.algo.name().to_string(),
            dataset: self.dataset.clone(),
            split: self.split.clone(),
            rounds: self.engine.config().rounds,
        }
    }

    /// Current global model.
    pub fn theta(&self) -> &[f32] {
        self.engine.theta()
    }

    /// Cumulative uplink bits so far.
    pub fn total_bits(&self) -> u64 {
        self.engine.total_bits()
    }

    /// Cumulative downlink (broadcast) bits so far.
    pub fn total_bits_down(&self) -> u64 {
        self.engine.total_bits_down()
    }

    /// Cumulative simulated wall-clock seconds so far (0 over the
    /// ideal network).
    pub fn total_sim_time(&self) -> f64 {
        self.engine.total_sim_time()
    }

    /// The simulated network scenario this session runs over.
    pub fn network(&self) -> &crate::transport::scenario::NetworkScenario {
        self.engine.network()
    }

    /// Per-device upload/skip counters (dense, O(M) — million-device
    /// callers should prefer
    /// [`RoundEngine::selection_stats`][super::engine::RoundEngine::selection_stats]
    /// via the engine).
    pub fn device_stats(&self) -> Vec<(u64, u64)> {
        self.engine.device_stats()
    }

    /// Fully-materialized device slots right now (live cache +
    /// in-flight cohort).
    pub fn resident_slots(&self) -> usize {
        self.engine.resident_slots()
    }

    /// Peak simultaneous fully-materialized device slots over the
    /// run's lifetime.
    pub fn peak_resident_slots(&self) -> usize {
        self.engine.peak_resident_slots()
    }

    /// The run configuration.
    pub fn config(&self) -> &RunConfig {
        self.engine.config()
    }

    /// Name of the active selection strategy.
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Execute one communication round (and notify observers). Under
    /// [`super::AggregationMode::Buffered`] a "round" is one committed
    /// model version of the event engine; the loop shape — indices,
    /// observers, checkpoint cadence — is identical to the sync path.
    pub fn run_round(&mut self, round: usize) -> RoundRecord {
        let rec = if self.engine.config().aggregation.is_sync() {
            self.engine.run_round(
                self.problem.as_ref(),
                self.algo.as_ref(),
                self.strategy.as_mut(),
                round,
            )
        } else {
            self.engine.run_buffered_round(
                self.problem.as_ref(),
                self.algo.as_ref(),
                self.strategy.as_mut(),
                round,
            )
        };
        for obs in &mut self.observers {
            obs.on_round(&rec);
        }
        rec
    }

    /// Run the full configured horizon, producing a trace. Observers
    /// see `on_run_start` / every round / `on_run_end`.
    pub fn run(&mut self) -> RunTrace {
        self.run_from(0)
    }

    /// Run rounds `start..rounds` — resuming a restored checkpoint picks
    /// up exactly where the snapshot left off. Observers still see
    /// `on_run_start` / `on_run_end`, and the trace holds only the
    /// rounds executed by this call.
    pub fn run_from(&mut self, start: usize) -> RunTrace {
        let rounds = self.engine.config().rounds;
        let meta = RunMeta {
            algorithm: self.algo.name().to_string(),
            dataset: self.dataset.clone(),
            split: self.split.clone(),
            rounds,
        };
        for obs in &mut self.observers {
            obs.on_run_start(&meta);
        }
        let mut trace = RunTrace {
            algorithm: meta.algorithm.clone(),
            dataset: meta.dataset.clone(),
            split: meta.split.clone(),
            rounds: Vec::with_capacity(rounds.saturating_sub(start)),
        };
        for k in start..rounds {
            trace.rounds.push(self.run_round(k));
            self.maybe_checkpoint(k + 1, rounds);
        }
        for obs in &mut self.observers {
            obs.on_run_end();
        }
        trace
    }

    /// Write a periodic checkpoint after each round (every `every`
    /// rounds and always after the final one) so a killed run can be
    /// resumed with `--resume`.
    pub fn checkpoint_to(&mut self, path: PathBuf, every: usize) {
        self.checkpoint = Some((path, every.max(1)));
    }

    fn maybe_checkpoint(&mut self, next_round: usize, rounds: usize) {
        let Some((path, every)) = self.checkpoint.clone() else {
            return;
        };
        if next_round % every == 0 || next_round == rounds {
            if let Err(e) = self.snapshot(next_round).save(&path) {
                eprintln!("warning: checkpoint to {} failed: {e}", path.display());
            }
        }
    }

    /// Snapshot the run state (resume with [`Session::restore`]).
    /// `next_round` is the index of the first round not yet executed.
    /// Selection-strategy and observer state are not captured (see
    /// DESIGN.md §4).
    pub fn snapshot(&self, next_round: usize) -> Checkpoint {
        self.engine.snapshot(next_round)
    }

    /// Restore a snapshot onto a session built with the same
    /// problem/masks/config. Returns the next round index to execute.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<usize> {
        self.engine.restore(ckpt)
    }
}
