//! The device population as a *spec*, not a vector of live slots.
//!
//! AQUILA's premise is that only a selected cohort of K devices uploads
//! each round, yet the pre-virtualization engine materialized a
//! `DeviceSlot` for every simulated device — O(population) memory and
//! per-round flag passes even when K ≪ N. A [`PopulationSpec`] instead
//! derives everything a device slot is *born with* — its capacity mask,
//! its resolved quantization sections, and its id-keyed RNG stream —
//! deterministically from `(seed, device_id)`, so the engine can
//! materialize full slot state lazily for just the selected cohort
//! (DESIGN.md §Population).
//!
//! Determinism argument: a fresh [`crate::algorithms::DeviceState`] is a
//! pure function of `(seed, id, mask, sections)`, and the mask/section
//! tables here are pure functions of `(layout, spec, id)`. Materializing
//! device `id` on round 40 therefore yields bit-identical state to
//! having materialized it on round 0 and never touched it — which is
//! exactly what the eager engine did. The equivalence is pinned by
//! `tests/prop_population.rs`.

use crate::algorithms::DeviceState;
use crate::hetero::{CapacityMask, MaskTable};
use crate::problems::ParamLayout;
use crate::quant::{SectionSpec, Sections};
use std::sync::Arc;

/// Deterministic derivation of per-device slot ingredients from
/// `(seed, device_id)`: capacity mask, resolved quantization sections,
/// and the device-keyed RNG stream seed. See the module docs.
#[derive(Clone, Debug)]
pub struct PopulationSpec {
    seed: u64,
    num_devices: usize,
    masks: MaskTable,
    /// Sections resolved once per *distinct* mask and keyed by mask
    /// identity (HeteroFL setups hand out two masks to M devices, not
    /// M distinct ones), so resolution cost is O(distinct masks) — not
    /// O(population).
    sections: Vec<(Arc<CapacityMask>, Arc<Sections>)>,
}

impl PopulationSpec {
    /// Resolve the spec for a population wearing `masks`, partitioning
    /// each device's upload per `section_spec` over `layout`.
    pub fn new(
        layout: &ParamLayout,
        masks: MaskTable,
        section_spec: &SectionSpec,
        seed: u64,
    ) -> Self {
        let sections = masks
            .distinct_masks()
            .into_iter()
            .map(|mask| {
                let s = Arc::new(section_spec.resolve(layout, &mask));
                (mask, s)
            })
            .collect();
        Self {
            seed,
            num_devices: masks.num_devices(),
            masks,
            sections,
        }
    }

    /// Total device count `M`.
    pub fn num_devices(&self) -> usize {
        self.num_devices
    }

    /// The run seed device RNG streams are keyed from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The population's capacity-mask table.
    pub fn masks(&self) -> &MaskTable {
        &self.masks
    }

    /// Capacity mask of `device` (panics out of range).
    pub fn mask_of(&self, device: usize) -> &Arc<CapacityMask> {
        self.masks.get(device)
    }

    /// Resolved quantization sections of `device` (panics out of
    /// range).
    pub fn sections_of(&self, device: usize) -> &Arc<Sections> {
        let key = Arc::as_ptr(self.masks.get(device));
        self.sections
            .iter()
            .find(|(m, _)| Arc::as_ptr(m) == key)
            .map(|(_, s)| s)
            .expect("every table mask is registered at construction")
    }

    /// Materialize device `device`'s algorithm state exactly as the
    /// eager engine would have at construction: zero reference vector,
    /// id-keyed RNG stream, the device's mask and sections.
    pub fn fresh_state(&self, device: usize) -> DeviceState {
        assert!(device < self.num_devices, "device {device} out of range");
        DeviceState::with_sections(
            device,
            self.mask_of(device).clone(),
            self.sections_of(device).clone(),
            self.seed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::half_half_masks;

    fn layout(d: usize) -> ParamLayout {
        ParamLayout::contiguous(&[("theta", vec![d])])
    }

    #[test]
    fn fresh_state_matches_eager_construction() {
        // The eager engine built every DeviceState up front from the
        // dense mask vector; the spec must produce bit-identical state
        // on demand, in any materialization order.
        let l = layout(10);
        let masks = half_half_masks(&l, 4, 0.5);
        let spec = PopulationSpec::new(
            &l,
            MaskTable::from(masks.clone()),
            &SectionSpec::Global,
            17,
        );
        for id in [3usize, 0, 2, 1] {
            let lazy = spec.fresh_state(id);
            let eager = DeviceState::with_sections(
                id,
                masks[id].clone(),
                Arc::new(SectionSpec::Global.resolve(&l, &masks[id])),
                17,
            );
            assert_eq!(lazy.id, eager.id);
            assert_eq!(lazy.q_prev, eager.q_prev);
            assert_eq!(lazy.mask.support(), eager.mask.support());
            assert_eq!(lazy.sections.total(), eager.sections.total());
            assert_eq!(lazy.rng.snapshot(), eager.rng.snapshot());
        }
    }

    #[test]
    fn sections_resolved_once_per_distinct_mask() {
        let l = layout(8);
        let spec = PopulationSpec::new(
            &l,
            MaskTable::half_half(&l, 1000, 0.5),
            &SectionSpec::Global,
            1,
        );
        assert_eq!(spec.sections.len(), 2);
        // Devices sharing a mask share the resolved sections object.
        assert!(Arc::ptr_eq(spec.sections_of(0), spec.sections_of(1)));
        assert!(Arc::ptr_eq(spec.sections_of(500), spec.sections_of(999)));
        assert!(!Arc::ptr_eq(spec.sections_of(0), spec.sections_of(999)));
    }

    #[test]
    fn million_device_spec_is_cheap_and_total() {
        let l = layout(16);
        let spec = PopulationSpec::new(
            &l,
            MaskTable::uniform_full(16, 1_000_000),
            &SectionSpec::Global,
            7,
        );
        assert_eq!(spec.num_devices(), 1_000_000);
        let s = spec.fresh_state(999_999);
        assert_eq!(s.id, 999_999);
        assert_eq!(s.support(), 16);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn fresh_state_rejects_out_of_range() {
        let l = layout(4);
        let spec =
            PopulationSpec::new(&l, MaskTable::uniform_full(4, 3), &SectionSpec::Global, 1);
        spec.fresh_state(3);
    }
}
