//! The table/figure reproduction harness: runs the experiment matrix
//! and prints rows in the paper's format. Used by the `repro` binary,
//! the benches, and the examples.

use crate::algorithms::{self, Algorithm};
use crate::config::ExperimentSpec;
use crate::coordinator::{Session, SessionBuilder};
use crate::hetero::{half_half_masks, CapacityMask, MaskTable};
use crate::metrics::{bits_display, RunTrace};
use crate::problems::GradientSource;
use crate::protocol::DeviceClient;
use std::path::Path;
use std::sync::Arc;

/// The per-device capacity masks an experiment cell runs with: the
/// Table III half-half split when `hetero`, full capacity everywhere
/// otherwise. Shared by [`session_for`] and the protocol's
/// [`crate::protocol::DeviceClient`], so both sides of a served run
/// construct identical device states.
pub fn masks_for(spec: &ExperimentSpec, problem: &dyn GradientSource) -> Vec<Arc<CapacityMask>> {
    if spec.hetero {
        half_half_masks(&problem.layout(), problem.num_devices(), 0.5)
    } else {
        vec![Arc::new(CapacityMask::full(problem.dim())); problem.num_devices()]
    }
}

/// [`masks_for`] as a compact [`MaskTable`] — O(1) regardless of the
/// device count, which is what virtualized (`--population`) runs must
/// use: a dense mask vector for 10⁷ devices would be O(population) on
/// its own.
pub fn mask_table_for(spec: &ExperimentSpec, problem: &dyn GradientSource) -> MaskTable {
    if spec.hetero {
        MaskTable::half_half(&problem.layout(), problem.num_devices(), 0.5)
    } else {
        MaskTable::uniform_full(problem.dim(), problem.num_devices())
    }
}

/// A configured [`SessionBuilder`] for one experiment cell — attach
/// observers or override the selection strategy before `build()`.
pub fn session_for(spec: &ExperimentSpec, algo: Arc<dyn Algorithm>) -> SessionBuilder {
    let problem: Arc<dyn GradientSource> = spec.build_problem().into();
    let masks = mask_table_for(spec, problem.as_ref());
    Session::builder(problem, algo)
        .config(spec.run_config())
        .selection_spec(spec.selection.clone())
        .dataset(spec.dataset.name())
        .split(spec.split.name(spec.dataset))
        .mask_table(masks)
}

/// Run one experiment cell (dataset × split × algorithm).
pub fn run_cell(spec: &ExperimentSpec, algo: Arc<dyn Algorithm>) -> RunTrace {
    session_for(spec, algo).build().run()
}

/// A [`crate::protocol::DeviceClient`] for one experiment cell,
/// constructed from the same problem/masks/config as [`session_for`]
/// so the client's device states mirror the coordinator's bit for
/// bit. Serve-spec heartbeat cadence is pre-applied; chain
/// [`crate::protocol::DeviceClient::reconnect`] etc. for resilience.
pub fn client_for(spec: &ExperimentSpec, algo: Arc<dyn Algorithm>) -> DeviceClient {
    let problem: Arc<dyn GradientSource> = spec.build_problem().into();
    let masks = mask_table_for(spec, problem.as_ref());
    DeviceClient::with_mask_table(problem, algo, spec.run_config(), masks)
        .heartbeat_ms(spec.serve.heartbeat_ms)
}

/// Format the headline metric (accuracy % for classification,
/// perplexity for LM) the way the tables print it.
pub fn metric_display(trace: &RunTrace) -> String {
    if let Some(acc) = trace.final_accuracy() {
        format!("{:.2}", acc * 100.0)
    } else if let Some(ppl) = trace.final_perplexity() {
        format!("{ppl:.2}")
    } else {
        format!("{:.3}", trace.final_train_loss())
    }
}

/// Run a full table (rows × the 7-algorithm suite) and print it in the
/// paper's row format. Traces are written as CSV under `out_dir` for
/// the figure series. Returns all traces keyed `(row_label, algo)`.
pub fn run_table(
    title: &str,
    rows: &[ExperimentSpec],
    out_dir: Option<&Path>,
) -> Vec<(String, String, RunTrace)> {
    let mut all = Vec::new();
    println!("\n=== {title} ===");
    println!(
        "{:<18} {:>10} | columns: Acc/PP  Cost(Gb)  [skip%]",
        "Row",
        ""
    );
    for spec in rows {
        let suite = algorithms::table_suite(spec.beta);
        let mut cells = Vec::new();
        for algo in &suite {
            let trace = run_cell(spec, algo.clone());
            if let Some(dir) = out_dir {
                let fname = format!(
                    "{}_{}_{}.csv",
                    spec.dataset.name().to_lowercase().replace('-', ""),
                    spec.split.name(spec.dataset).to_lowercase().replace('-', ""),
                    algo.name().to_lowercase()
                );
                trace.write_csv(&dir.join(fname)).expect("writing trace csv");
            }
            cells.push((algo.name().to_string(), trace));
        }
        print!("{:<18}", spec.row_label());
        for (name, trace) in &cells {
            let total = trace.total_uploads() + trace.total_skips();
            let skip_pct = if total > 0 {
                100.0 * trace.total_skips() as f64 / total as f64
            } else {
                0.0
            };
            print!(
                " | {} {}/{} [{:.0}%]",
                name,
                metric_display(trace),
                bits_display(trace.total_bits()),
                skip_pct
            );
        }
        println!();
        for (name, trace) in cells {
            all.push((spec.row_label(), name, trace));
        }
    }
    // AQUILA-vs-baseline savings summary (the paper's headline claims).
    print_savings(&all);
    all
}

/// Print AQUILA's bit savings vs each baseline, averaged over rows —
/// the quantities behind "AQUILA reduces 60.4% overall communication
/// costs compared to LENA and 57.2% compared to MARINA on average".
pub fn print_savings(all: &[(String, String, RunTrace)]) {
    use std::collections::BTreeMap;
    let mut by_row: BTreeMap<&str, BTreeMap<&str, u64>> = BTreeMap::new();
    for (row, algo, trace) in all {
        by_row
            .entry(row)
            .or_default()
            .insert(algo, trace.total_bits());
    }
    let mut savings: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for cells in by_row.values() {
        let Some(&aq) = cells.get("AQUILA") else {
            continue;
        };
        for (algo, &bits) in cells {
            if *algo != "AQUILA" && bits > 0 {
                savings
                    .entry(algo)
                    .or_default()
                    .push(100.0 * (1.0 - aq as f64 / bits as f64));
            }
        }
    }
    println!("\nAQUILA average bit savings vs baselines:");
    for (algo, s) in savings {
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        println!("  vs {algo:<12} {mean:>6.1}%");
    }
}

/// The β-ablation sweep (Figures 4 and 5): run AQUILA at several β on
/// one dataset row, returning `(β, trace)` pairs.
pub fn ablation_beta(spec: &ExperimentSpec, betas: &[f32]) -> Vec<(f32, RunTrace)> {
    betas
        .iter()
        .map(|&beta| {
            let mut s = spec.clone();
            s.beta = beta;
            (beta, run_cell(&s, Arc::new(algorithms::aquila::Aquila::new(beta))))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, SplitKind};

    fn tiny_spec() -> ExperimentSpec {
        let mut s =
            ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false).scaled(0.02, 12);
        s.devices = 4;
        s
    }

    #[test]
    fn run_cell_produces_trace() {
        let spec = tiny_spec();
        let t = run_cell(&spec, Arc::new(algorithms::aquila::Aquila::new(spec.beta)));
        assert_eq!(t.rounds.len(), 12);
        assert!(t.total_bits() > 0);
        assert_eq!(t.algorithm, "AQUILA");
    }

    #[test]
    fn hetero_cell_cheaper_than_homogeneous() {
        let spec = tiny_spec();
        let mut hetero = spec.clone();
        hetero.hetero = true;
        let t_homo = run_cell(&spec, Arc::new(algorithms::fedavg::FedAvg));
        let t_het = run_cell(&hetero, Arc::new(algorithms::fedavg::FedAvg));
        assert!(t_het.total_bits() < t_homo.total_bits());
    }

    #[test]
    fn run_cell_honors_selection_spec() {
        use crate::selection::SelectionSpec;
        let mut spec = tiny_spec();
        spec.selection = SelectionSpec::RoundRobin(2);
        let t = run_cell(&spec, Arc::new(algorithms::fedavg::FedAvg));
        assert!(t.rounds.iter().all(|r| r.uploads <= 2));
        assert!(t.total_uploads() > 0);
    }

    #[test]
    fn ablation_zero_beta_never_skips() {
        let spec = tiny_spec();
        let out = ablation_beta(&spec, &[0.0, 5.0]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.total_skips(), 0);
        // Large β skips strictly more.
        assert!(out[1].1.total_skips() > 0);
        assert!(out[1].1.total_bits() < out[0].1.total_bits());
    }

    #[test]
    fn metric_display_formats() {
        let spec = tiny_spec();
        let t = run_cell(&spec, Arc::new(algorithms::fedavg::FedAvg));
        let m = metric_display(&t);
        assert!(m.parse::<f64>().is_ok());
    }
}
