//! Gaussian-mixture synthetic classification datasets (CIFAR stand-in).
//!
//! Each class `c` has a mean vector `μ_c ~ separation · N(0, I)`; samples
//! are `x = μ_c + N(0, σ² I)`. With `separation ≈ σ` the task is
//! non-trivially learnable: linear/MLP models show a realistic descending
//! loss curve, which is what drives the gradient-innovation dynamics the
//! quantization algorithms react to.

use super::ClassificationDataset;
use crate::util::rng::Xoshiro256pp;

/// Configuration for [`gaussian_mixture`].
#[derive(Clone, Debug)]
pub struct MixtureSpec {
    /// Number of mixture components / label classes.
    pub num_classes: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Total sample count (split evenly over classes, remainder to the
    /// first classes).
    pub num_samples: usize,
    /// Scale of class means.
    pub separation: f32,
    /// Within-class noise std.
    pub noise: f32,
    /// Generator seed.
    pub seed: u64,
}

impl MixtureSpec {
    /// CIFAR-10-like stand-in: 10 classes, 64-dim features. The
    /// separation/noise ratio is tuned so a trained classifier lands in
    /// the paper's CF-10 accuracy band (~90 %) rather than saturating.
    pub fn cifar10_like(num_samples: usize, seed: u64) -> Self {
        Self {
            num_classes: 10,
            dim: 64,
            num_samples,
            separation: 0.28,
            noise: 1.0,
            seed,
        }
    }

    /// CIFAR-100-like stand-in: 100 classes, 128-dim features
    /// (separation tuned for a ~50–80 % accuracy band as in the paper's
    /// CF-100 rows).
    pub fn cifar100_like(num_samples: usize, seed: u64) -> Self {
        Self {
            num_classes: 100,
            dim: 128,
            num_samples,
            separation: 0.22,
            noise: 1.0,
            seed,
        }
    }
}

/// Generate a Gaussian-mixture dataset. Deterministic in `spec.seed`.
pub fn gaussian_mixture(spec: &MixtureSpec) -> ClassificationDataset {
    assert!(spec.num_classes >= 2);
    assert!(spec.dim >= 1);
    let mut rng = Xoshiro256pp::stream(spec.seed, 0xDA7A);
    // Class means.
    let mut means = vec![0.0f32; spec.num_classes * spec.dim];
    for m in means.iter_mut() {
        *m = rng.gaussian_f32(0.0, spec.separation);
    }
    let mut features = Vec::with_capacity(spec.num_samples * spec.dim);
    let mut labels = Vec::with_capacity(spec.num_samples);
    for i in 0..spec.num_samples {
        let c = i % spec.num_classes;
        let mu = &means[c * spec.dim..(c + 1) * spec.dim];
        for &m in mu {
            features.push(m + rng.gaussian_f32(0.0, spec.noise));
        }
        labels.push(c);
    }
    // Shuffle samples so device shards are not class-ordered by default.
    let mut order: Vec<usize> = (0..spec.num_samples).collect();
    rng.shuffle(&mut order);
    let ds = ClassificationDataset {
        features,
        labels,
        dim: spec.dim,
        num_classes: spec.num_classes,
    };
    ds.subset(&order)
}

/// A train/test pair drawn from the same mixture (disjoint samples).
pub fn train_test_split(
    spec: &MixtureSpec,
    test_fraction: f64,
) -> (ClassificationDataset, ClassificationDataset) {
    let full = gaussian_mixture(spec);
    let n_test = ((full.len() as f64) * test_fraction).round() as usize;
    let n_test = n_test.clamp(1, full.len().saturating_sub(1));
    let test_idx: Vec<usize> = (0..n_test).collect();
    let train_idx: Vec<usize> = (n_test..full.len()).collect();
    (full.subset(&train_idx), full.subset(&test_idx))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = MixtureSpec::cifar10_like(500, 7);
        let a = gaussian_mixture(&spec);
        let b = gaussian_mixture(&spec);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let a = gaussian_mixture(&MixtureSpec::cifar10_like(100, 1));
        let b = gaussian_mixture(&MixtureSpec::cifar10_like(100, 2));
        assert_ne!(a.features, b.features);
    }

    #[test]
    fn all_classes_present_and_balanced() {
        let spec = MixtureSpec::cifar10_like(1000, 3);
        let ds = gaussian_mixture(&spec);
        let mut counts = vec![0usize; 10];
        for &l in &ds.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn shapes_consistent() {
        let ds = gaussian_mixture(&MixtureSpec::cifar100_like(250, 5));
        assert_eq!(ds.len(), 250);
        assert_eq!(ds.features.len(), 250 * 128);
        assert_eq!(ds.num_classes, 100);
        assert!(ds.labels.iter().all(|&l| l < 100));
    }

    #[test]
    fn subset_selects_rows() {
        let ds = gaussian_mixture(&MixtureSpec::cifar10_like(50, 9));
        let sub = ds.subset(&[3, 7]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.row(0), ds.row(3));
        assert_eq!(sub.row(1), ds.row(7));
        assert_eq!(sub.labels, vec![ds.labels[3], ds.labels[7]]);
    }

    #[test]
    fn train_test_disjoint_sizes() {
        let spec = MixtureSpec::cifar10_like(200, 11);
        let (train, test) = train_test_split(&spec, 0.25);
        assert_eq!(train.len(), 150);
        assert_eq!(test.len(), 50);
    }

    #[test]
    fn classes_are_separable_better_than_chance() {
        // Nearest-class-mean classification on held-out data should beat
        // chance by a wide margin — sanity check that the task is
        // learnable at all.
        let spec = MixtureSpec {
            num_classes: 10,
            dim: 64,
            num_samples: 2000,
            separation: 1.0,
            noise: 1.0,
            seed: 13,
        };
        let (train, test) = train_test_split(&spec, 0.2);
        let k = train.num_classes;
        let mut means = vec![0.0f64; k * train.dim];
        let mut counts = vec![0usize; k];
        for i in 0..train.len() {
            let c = train.labels[i];
            counts[c] += 1;
            for (j, &x) in train.row(i).iter().enumerate() {
                means[c * train.dim + j] += x as f64;
            }
        }
        for c in 0..k {
            for j in 0..train.dim {
                means[c * train.dim + j] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let row = test.row(i);
            let best = (0..k)
                .min_by(|&a, &b| {
                    let da: f64 = row
                        .iter()
                        .enumerate()
                        .map(|(j, &x)| (x as f64 - means[a * test.dim + j]).powi(2))
                        .sum();
                    let db: f64 = row
                        .iter()
                        .enumerate()
                        .map(|(j, &x)| (x as f64 - means[b * test.dim + j]).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy only {acc}");
    }
}
