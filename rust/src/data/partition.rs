//! Federated data partitioners: IID and Non-IID splits.
//!
//! The paper's Non-IID protocol (Section V-B, following HeteroFL [27]):
//! "each device is allocated two classes of data in CIFAR-10 and 10
//! classes of data in CIFAR-100 at most, and the amount of data for each
//! label is balanced". [`label_limited_partition`] implements exactly
//! that via the classic shard construction (sort by label, deal
//! `classes_per_device` shards to each device).

use crate::util::rng::Xoshiro256pp;

/// Split `n` sample indices IID across `m` devices (near-equal sizes,
/// random assignment).
pub fn iid_partition(n: usize, m: usize, rng: &mut Xoshiro256pp) -> Vec<Vec<usize>> {
    assert!(m >= 1 && n >= m, "need at least one sample per device");
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let base = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut cursor = 0;
    for dev in 0..m {
        let take = base + usize::from(dev < extra);
        out.push(idx[cursor..cursor + take].to_vec());
        cursor += take;
    }
    out
}

/// Non-IID label-limited partition: each device receives data from at
/// most `classes_per_device` classes, with per-label balance.
///
/// Construction: group indices by label, cut each label group into equal
/// shards so that the total shard count is `m · classes_per_device`,
/// shuffle shards, deal `classes_per_device` shards per device.
pub fn label_limited_partition(
    labels: &[usize],
    num_classes: usize,
    m: usize,
    classes_per_device: usize,
    rng: &mut Xoshiro256pp,
) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    assert!(classes_per_device >= 1);
    let total_shards = m * classes_per_device;
    // Group by label.
    let mut by_label: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < num_classes);
        by_label[l].push(i);
    }
    // Degenerate regime: fewer class slots (m · c) than distinct
    // classes — the per-device class cap cannot hold while covering all
    // data. Fall back to a label-sorted contiguous cut (devices still
    // see few-class shards, approximately c each), prioritizing
    // coverage. Real experiment presets never hit this; tiny smoke
    // configs do.
    let nonempty_count = by_label.iter().filter(|g| !g.is_empty()).count();
    if total_shards < nonempty_count {
        let mut sorted: Vec<usize> = Vec::with_capacity(labels.len());
        for group in &by_label {
            sorted.extend_from_slice(group);
        }
        let per = sorted.len() / m;
        return (0..m)
            .map(|dev| {
                let start = dev * per;
                let end = if dev == m - 1 { sorted.len() } else { start + per };
                sorted[start..end].to_vec()
            })
            .collect();
    }
    // Shards per label proportional to its mass; at least 1 shard per
    // non-empty label.
    let n = labels.len();
    assert!(
        total_shards <= n,
        "cannot cut {n} samples into {total_shards} shards"
    );
    let mut shards: Vec<Vec<usize>> = Vec::with_capacity(total_shards);
    let nonempty: Vec<usize> = (0..num_classes).filter(|&c| !by_label[c].is_empty()).collect();
    // Round-robin remainders so shard counts sum exactly to total_shards.
    let mut counts: Vec<usize> = nonempty
        .iter()
        .map(|&c| (by_label[c].len() * total_shards) / n)
        .collect();
    let mut assigned: usize = counts.iter().sum();
    // Fix up: ensure every non-empty class has ≥ 1 shard and the total is
    // exact.
    for k in 0..counts.len() {
        if counts[k] == 0 {
            counts[k] = 1;
            assigned += 1;
        }
    }
    let nclasses = counts.len();
    let mut k = 0;
    while assigned > total_shards {
        let idx = k % nclasses;
        if counts[idx] > 1 {
            counts[idx] -= 1;
            assigned -= 1;
        }
        k += 1;
    }
    k = 0;
    while assigned < total_shards {
        counts[k % nclasses] += 1;
        assigned += 1;
        k += 1;
    }
    for (slot, &c) in nonempty.iter().enumerate() {
        let group = &mut by_label[c];
        rng.shuffle(group);
        let s = counts[slot];
        let per = group.len() / s;
        for j in 0..s {
            let start = j * per;
            let end = if j == s - 1 { group.len() } else { start + per };
            shards.push(group[start..end].to_vec());
        }
    }
    // Deal shards to devices. To respect the classes-per-device cap we
    // greedily assign shards to the device with the fewest shards that
    // either already holds this shard's class or still has class budget.
    let shard_class: Vec<usize> = {
        let mut sc = Vec::with_capacity(shards.len());
        for s in &shards {
            sc.push(labels[s[0]]);
        }
        sc
    };
    let mut order: Vec<usize> = (0..shards.len()).collect();
    rng.shuffle(&mut order);
    let mut dev_classes: Vec<Vec<usize>> = vec![Vec::new(); m];
    let mut dev_shard_count = vec![0usize; m];
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); m];
    for &si in &order {
        let class = shard_class[si];
        // Candidate devices: those already holding the class, else those
        // with spare class budget; tie-break on fewest shards.
        let mut best: Option<usize> = None;
        for dev in 0..m {
            let holds = dev_classes[dev].contains(&class);
            let budget_ok = holds || dev_classes[dev].len() < classes_per_device;
            if !budget_ok || dev_shard_count[dev] >= classes_per_device {
                continue;
            }
            match best {
                None => best = Some(dev),
                Some(b) => {
                    if dev_shard_count[dev] < dev_shard_count[b] {
                        best = Some(dev);
                    }
                }
            }
        }
        // Fallback (rare with adversarial class distributions): device
        // with fewest shards regardless of class budget.
        let dev = best.unwrap_or_else(|| {
            (0..m).min_by_key(|&d| dev_shard_count[d]).unwrap()
        });
        if !dev_classes[dev].contains(&class) {
            dev_classes[dev].push(class);
        }
        dev_shard_count[dev] += 1;
        out[dev].extend_from_slice(&shards[si]);
    }
    out
}

/// Count the distinct classes held by each device (test/diagnostic
/// helper).
pub fn classes_per_device(parts: &[Vec<usize>], labels: &[usize]) -> Vec<usize> {
    parts
        .iter()
        .map(|p| {
            let mut cs: Vec<usize> = p.iter().map(|&i| labels[i]).collect();
            cs.sort_unstable();
            cs.dedup();
            cs.len()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced_labels(n: usize, k: usize) -> Vec<usize> {
        (0..n).map(|i| i % k).collect()
    }

    #[test]
    fn iid_covers_everything_once() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let parts = iid_partition(103, 10, &mut rng);
        assert_eq!(parts.len(), 10);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // Sizes differ by at most 1.
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn label_limited_respects_class_cap() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let labels = balanced_labels(2000, 10);
        let parts = label_limited_partition(&labels, 10, 100, 2, &mut rng);
        let counts = classes_per_device(&parts, &labels);
        // Paper: at most 2 classes per device on CIFAR-10.
        assert!(counts.iter().all(|&c| c <= 2), "counts={counts:?}");
        // Everything assigned exactly once.
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..2000).collect::<Vec<_>>());
    }

    #[test]
    fn label_limited_cifar100_style() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let labels = balanced_labels(5000, 100);
        let parts = label_limited_partition(&labels, 100, 100, 10, &mut rng);
        let counts = classes_per_device(&parts, &labels);
        assert!(counts.iter().all(|&c| c <= 10), "max={:?}", counts.iter().max());
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 5000);
    }

    #[test]
    fn label_limited_no_empty_devices() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let labels = balanced_labels(1000, 10);
        let parts = label_limited_partition(&labels, 10, 20, 2, &mut rng);
        assert!(parts.iter().all(|p| !p.is_empty()));
    }

    #[test]
    fn label_limited_is_actually_non_iid() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let labels = balanced_labels(2000, 10);
        let parts = label_limited_partition(&labels, 10, 50, 2, &mut rng);
        let counts = classes_per_device(&parts, &labels);
        // Strictly fewer classes than the global 10 on every device.
        assert!(counts.iter().all(|&c| c < 10));
    }

    #[test]
    fn unbalanced_labels_still_partition() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        // Class 0 has 10x the mass of others.
        let mut labels = Vec::new();
        for i in 0..1100 {
            labels.push(if i < 1000 { 0 } else { 1 + (i % 5) });
        }
        let parts = label_limited_partition(&labels, 6, 10, 2, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 1100);
    }

    #[test]
    #[should_panic]
    fn iid_rejects_more_devices_than_samples() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        iid_partition(3, 10, &mut rng);
    }
}
