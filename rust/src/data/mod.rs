//! Synthetic datasets and federated partitioners.
//!
//! The paper evaluates on CIFAR-10, CIFAR-100 and WikiText-2; none are
//! available in this offline environment, so per DESIGN.md §3 we
//! substitute synthetic generators whose *gradient processes* exercise
//! the same code paths: a Gaussian-mixture classifier dataset
//! ([`synth`]) standing in for CIFAR, and a Markov-chain character
//! corpus ([`text`]) standing in for WikiText-2. Partitioners
//! ([`partition`]) implement the paper's IID and Non-IID
//! (c-classes-per-device, HeteroFL-style) splits.

pub mod partition;
pub mod synth;
pub mod text;

/// A dense classification dataset with row-major features.
#[derive(Clone, Debug)]
pub struct ClassificationDataset {
    /// `n × dim`, row-major.
    pub features: Vec<f32>,
    /// `n` labels in `[0, num_classes)`.
    pub labels: Vec<usize>,
    /// Feature dimension.
    pub dim: usize,
    /// Number of label classes.
    pub num_classes: usize,
}

impl ClassificationDataset {
    /// Sample count `n`.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Restrict to a subset of indices (device shard).
    pub fn subset(&self, idx: &[usize]) -> ClassificationDataset {
        let mut features = Vec::with_capacity(idx.len() * self.dim);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            features.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        ClassificationDataset {
            features,
            labels,
            dim: self.dim,
            num_classes: self.num_classes,
        }
    }
}

/// A token-stream dataset for next-token language modelling.
#[derive(Clone, Debug)]
pub struct TokenDataset {
    /// The token stream.
    pub tokens: Vec<u16>,
    /// Vocabulary size `V`.
    pub vocab: usize,
}

impl TokenDataset {
    /// Token count.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True when the stream holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Contiguous chunk `[start, end)`.
    pub fn slice(&self, start: usize, end: usize) -> TokenDataset {
        TokenDataset {
            tokens: self.tokens[start..end].to_vec(),
            vocab: self.vocab,
        }
    }
}
