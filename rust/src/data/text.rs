//! Markov-chain character corpus (WikiText-2 stand-in).
//!
//! A fixed-seed first-order Markov chain over a `V`-symbol alphabet with
//! peaked transition rows generates a corpus whose next-token
//! distribution is learnable (achievable perplexity well below `V`) but
//! not trivial. Language-model training on this corpus produces the
//! descending-perplexity curves the WT-2 rows of Tables II/III report.

use super::TokenDataset;
use crate::util::rng::Xoshiro256pp;

/// Configuration for [`markov_corpus`].
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Alphabet size (the paper's WT-2 rows use word-level; we use a
    /// character-scale vocab, default 64).
    pub vocab: usize,
    /// Corpus length in tokens.
    pub length: usize,
    /// Concentration of transition rows: each row is a softmax of
    /// `peakedness · N(0,1)` logits; larger = lower-entropy = lower
    /// achievable perplexity.
    pub peakedness: f64,
    /// Generator seed.
    pub seed: u64,
}

impl CorpusSpec {
    /// WikiText-2-like stand-in: 64-token vocab, peakedness tuned for a
    /// perplexity band comparable to the paper's WT-2 rows.
    pub fn wikitext2_like(length: usize, seed: u64) -> Self {
        Self {
            vocab: 64,
            length,
            peakedness: 2.0,
            seed,
        }
    }
}

/// The generator: transition matrix + sampling state.
#[derive(Clone, Debug)]
pub struct MarkovChain {
    /// Vocabulary size `V`.
    pub vocab: usize,
    /// Row-major `V × V` transition probabilities.
    pub trans: Vec<f64>,
}

impl MarkovChain {
    /// Build the chain's transition matrix from `spec` (deterministic in
    /// `spec.seed`).
    pub fn from_spec(spec: &CorpusSpec) -> Self {
        assert!(spec.vocab >= 2 && spec.vocab <= u16::MAX as usize + 1);
        let v = spec.vocab;
        let mut rng = Xoshiro256pp::stream(spec.seed, 0x7E87);
        let mut trans = vec![0.0f64; v * v];
        for r in 0..v {
            let row = &mut trans[r * v..(r + 1) * v];
            let mut maxl = f64::NEG_INFINITY;
            for x in row.iter_mut() {
                *x = spec.peakedness * rng.next_gaussian();
                maxl = maxl.max(*x);
            }
            let mut z = 0.0;
            for x in row.iter_mut() {
                *x = (*x - maxl).exp();
                z += *x;
            }
            for x in row.iter_mut() {
                *x /= z;
            }
        }
        Self { vocab: v, trans }
    }

    /// Entropy rate (bits-free: natural log) under the stationary
    /// distribution approximated by the uniform start — used by tests to
    /// check the achievable-perplexity floor.
    pub fn mean_row_entropy(&self) -> f64 {
        let v = self.vocab;
        let mut h = 0.0;
        for r in 0..v {
            for c in 0..v {
                let p = self.trans[r * v + c];
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
        }
        h / v as f64
    }

    fn sample_next(&self, cur: usize, rng: &mut Xoshiro256pp) -> usize {
        let row = &self.trans[cur * self.vocab..(cur + 1) * self.vocab];
        let mut u = rng.next_f64();
        for (i, &p) in row.iter().enumerate() {
            if u < p {
                return i;
            }
            u -= p;
        }
        self.vocab - 1
    }
}

/// Generate a corpus from the chain defined by `spec`.
pub fn markov_corpus(spec: &CorpusSpec) -> TokenDataset {
    let chain = MarkovChain::from_spec(spec);
    let mut rng = Xoshiro256pp::stream(spec.seed, 0xC0&0xFFFF | 0xC0FF);
    let mut tokens = Vec::with_capacity(spec.length);
    let mut cur = rng.next_bounded(spec.vocab as u64) as usize;
    for _ in 0..spec.length {
        tokens.push(cur as u16);
        cur = chain.sample_next(cur, &mut rng);
    }
    TokenDataset {
        tokens,
        vocab: spec.vocab,
    }
}

/// Split a corpus into `m` contiguous device shards (IID in the sense of
/// the paper's WT-2 setting: every shard comes from the same chain).
pub fn shard_corpus(ds: &TokenDataset, m: usize) -> Vec<TokenDataset> {
    assert!(m >= 1 && ds.len() >= m);
    let chunk = ds.len() / m;
    (0..m)
        .map(|i| {
            let start = i * chunk;
            let end = if i == m - 1 { ds.len() } else { start + chunk };
            ds.slice(start, end)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = CorpusSpec::wikitext2_like(5000, 42);
        assert_eq!(markov_corpus(&spec).tokens, markov_corpus(&spec).tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let ds = markov_corpus(&CorpusSpec::wikitext2_like(10_000, 1));
        assert!(ds.tokens.iter().all(|&t| (t as usize) < ds.vocab));
        assert_eq!(ds.len(), 10_000);
    }

    #[test]
    fn rows_are_distributions() {
        let chain = MarkovChain::from_spec(&CorpusSpec::wikitext2_like(10, 3));
        for r in 0..chain.vocab {
            let s: f64 = chain.trans[r * chain.vocab..(r + 1) * chain.vocab]
                .iter()
                .sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn corpus_is_learnable_below_uniform() {
        // Entropy rate must be well below ln(V) (uniform), i.e. a model
        // that learns the chain beats perplexity V.
        let spec = CorpusSpec::wikitext2_like(10, 7);
        let chain = MarkovChain::from_spec(&spec);
        let h = chain.mean_row_entropy();
        let uniform = (spec.vocab as f64).ln();
        assert!(h < 0.8 * uniform, "h={h}, uniform={uniform}");
        assert!(h > 0.05 * uniform, "degenerate chain");
    }

    #[test]
    fn empirical_bigram_stats_match_chain() {
        let spec = CorpusSpec::wikitext2_like(200_000, 5);
        let chain = MarkovChain::from_spec(&spec);
        let ds = markov_corpus(&spec);
        // Empirical P(next | cur=0) vs chain row 0.
        let v = spec.vocab;
        let mut counts = vec![0usize; v];
        let mut total = 0usize;
        for w in ds.tokens.windows(2) {
            if w[0] == 0 {
                counts[w[1] as usize] += 1;
                total += 1;
            }
        }
        assert!(total > 500);
        for c in 0..v {
            let emp = counts[c] as f64 / total as f64;
            let truth = chain.trans[c];
            assert!(
                (emp - truth).abs() < 0.05,
                "class {c}: emp {emp} vs chain {truth}"
            );
        }
    }

    #[test]
    fn sharding_covers_everything() {
        let ds = markov_corpus(&CorpusSpec::wikitext2_like(1003, 9));
        let shards = shard_corpus(&ds, 8);
        assert_eq!(shards.len(), 8);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 1003);
        // Last shard absorbs the remainder.
        assert_eq!(shards[7].len(), 1003 - 7 * 125);
    }
}
