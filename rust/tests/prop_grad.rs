//! Property tests for the batched GEMM-backed compute layer
//! (`problems` + `util::gemm`):
//!
//! * every batched gradient matches its retained naive per-sample
//!   reference within 1e-4 relative tolerance on random θ;
//! * `local_grad` is exactly deterministic — bit-identical across
//!   repeated calls and across fresh/reused [`GradScratch`] instances;
//! * whole-run traces are bit-identical for engine thread counts
//!   1 / 2 / 7 (the workspaces are per-device, so the thread partition
//!   cannot influence any gradient).

use aquila::algorithms::aquila::Aquila;
use aquila::coordinator::{RunConfig, Session};
use aquila::data::partition::iid_partition;
use aquila::data::synth::{train_test_split, MixtureSpec};
use aquila::data::text::{markov_corpus, shard_corpus, CorpusSpec};
use aquila::data::ClassificationDataset;
use aquila::problems::cnn::CnnProblem;
use aquila::problems::logistic::LogisticProblem;
use aquila::problems::mlp::MlpProblem;
use aquila::problems::softmax_lm::SoftmaxLmProblem;
use aquila::problems::GradientSource;
use aquila::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// |a − b| ≤ tol · max(|a|, |b|, ‖g_ref‖_∞) elementwise — relative
/// tolerance with a gradient-scale floor so near-cancelled entries
/// compare at the accumulation noise floor, not at ±∞ relative error.
fn assert_grad_close(got: &[f32], want: &[f32], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len());
    let scale = want.iter().fold(0.0f64, |m, &x| m.max((x as f64).abs())).max(1e-6);
    for (i, (&a, &b)) in got.iter().zip(want).enumerate() {
        let (a, b) = (a as f64, b as f64);
        let denom = a.abs().max(b.abs()).max(scale);
        assert!(
            (a - b).abs() <= tol * denom,
            "{what}[{i}]: batched {a} vs naive {b} (denom {denom})"
        );
    }
}

fn assert_loss_close(got: f64, want: f64, what: &str) {
    assert!(
        (got - want).abs() <= 1e-5 * want.abs().max(1.0),
        "{what}: batched loss {got} vs naive {want}"
    );
}

fn mixture_shards(
    spec: &MixtureSpec,
    devices: usize,
    part_seed: u64,
) -> (Vec<ClassificationDataset>, ClassificationDataset) {
    let (train, test) = train_test_split(spec, 0.2);
    let mut rng = Xoshiro256pp::seed_from_u64(part_seed);
    let parts = iid_partition(train.len(), devices, &mut rng);
    (parts.iter().map(|p| train.subset(p)).collect(), test)
}

fn logistic_problem(seed: u64) -> LogisticProblem {
    let spec = MixtureSpec {
        num_classes: 5,
        dim: 13,
        num_samples: 420,
        separation: 1.2,
        noise: 1.0,
        seed,
    };
    let (shards, test) = mixture_shards(&spec, 4, seed ^ 0xA1);
    LogisticProblem::new(shards, test, 1e-3)
}

fn mlp_problem(seed: u64) -> MlpProblem {
    let spec = MixtureSpec {
        num_classes: 4,
        dim: 10,
        num_samples: 360,
        separation: 1.2,
        noise: 0.9,
        seed,
    };
    let (shards, test) = mixture_shards(&spec, 3, seed ^ 0xB2);
    MlpProblem::new(shards, test, 12, 1e-4)
}

fn cnn_problem(seed: u64) -> CnnProblem {
    let spec = MixtureSpec {
        num_classes: 3,
        dim: 64, // 8×8 images
        num_samples: 270,
        separation: 1.0,
        noise: 0.8,
        seed,
    };
    let (shards, test) = mixture_shards(&spec, 3, seed ^ 0xC3);
    CnnProblem::new(shards, test, 4, 3, 1e-4)
}

fn lm_problem(seed: u64) -> SoftmaxLmProblem {
    let spec = CorpusSpec {
        vocab: 12,
        length: 9_000,
        peakedness: 1.8,
        seed,
    };
    let full = markov_corpus(&spec);
    let test = full.slice(0, 1500);
    let train = full.slice(1500, full.len());
    SoftmaxLmProblem::new(shard_corpus(&train, 3), test, 1e-4)
}

/// Random θ in the rough magnitude band training visits.
fn random_theta(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..d).map(|_| rng.gaussian_f32(0.0, 0.4)).collect()
}

/// Run the batched-vs-naive comparison over random θ and every device.
fn check_against_naive<P, F>(problem: &P, naive: F, tol: f64, what: &str)
where
    P: GradientSource,
    F: Fn(&P, usize, &[f32], &mut [f32]) -> f64,
{
    let d = problem.dim();
    let mut ws = problem.make_scratch();
    let mut g = vec![0.0f32; d];
    let mut g_ref = vec![0.0f32; d];
    for trial in 0..3u64 {
        let theta = random_theta(d, 0x5EED ^ (trial * 977));
        for dev in 0..problem.num_devices() {
            let loss = problem.local_grad(dev, &theta, &mut g, &mut ws);
            let loss_ref = naive(problem, dev, &theta, &mut g_ref);
            assert_loss_close(loss, loss_ref, what);
            assert_grad_close(&g, &g_ref, tol, what);
        }
    }
}

#[test]
fn prop_logistic_batched_matches_naive() {
    for seed in [11u64, 12, 13] {
        let p = logistic_problem(seed);
        check_against_naive(&p, LogisticProblem::local_grad_naive, 1e-4, "logistic");
    }
}

#[test]
fn prop_mlp_batched_matches_naive() {
    for seed in [21u64, 22, 23] {
        let p = mlp_problem(seed);
        check_against_naive(&p, MlpProblem::local_grad_naive, 1e-4, "mlp");
    }
}

#[test]
fn prop_cnn_batched_matches_naive() {
    for seed in [31u64, 32, 33] {
        let p = cnn_problem(seed);
        check_against_naive(&p, CnnProblem::local_grad_naive, 1e-4, "cnn");
    }
}

#[test]
fn prop_softmax_lm_batched_matches_naive() {
    for seed in [41u64, 42] {
        let p = lm_problem(seed);
        check_against_naive(&p, SoftmaxLmProblem::local_grad_naive, 1e-4, "softmax_lm");
    }
}

/// Bitwise determinism of `local_grad`: repeated calls with a reused
/// scratch, and calls with a fresh scratch, must agree exactly.
fn check_bitwise_determinism<P: GradientSource>(problem: &P, what: &str) {
    let d = problem.dim();
    let theta = random_theta(d, 0xD1CE);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    let mut ws = problem.make_scratch();
    let mut g = vec![0.0f32; d];
    for dev in 0..problem.num_devices() {
        let l1 = problem.local_grad(dev, &theta, &mut g, &mut ws);
        let b1 = bits(&g);
        // Same (now warm) scratch.
        let l2 = problem.local_grad(dev, &theta, &mut g, &mut ws);
        assert_eq!(l1.to_bits(), l2.to_bits(), "{what}: loss drifted on reuse");
        assert_eq!(b1, bits(&g), "{what}: grad drifted on scratch reuse");
        // Fresh scratch.
        let mut fresh = problem.make_scratch();
        let l3 = problem.local_grad(dev, &theta, &mut g, &mut fresh);
        assert_eq!(l1.to_bits(), l3.to_bits(), "{what}: loss depends on scratch");
        assert_eq!(b1, bits(&g), "{what}: grad depends on scratch instance");
    }
}

#[test]
fn prop_local_grad_bitwise_deterministic() {
    check_bitwise_determinism(&logistic_problem(51), "logistic");
    check_bitwise_determinism(&mlp_problem(52), "mlp");
    check_bitwise_determinism(&cnn_problem(53), "cnn");
    check_bitwise_determinism(&lm_problem(54), "softmax_lm");
}

/// Full-session determinism across engine thread counts on a batched
/// (MLP) problem: per-round losses, total bits, and the final model are
/// bit-identical for threads ∈ {1, 2, 7}.
#[test]
fn prop_trace_bitwise_identical_across_threads() {
    let cfg = |threads: usize| RunConfig {
        alpha: 0.3,
        beta: 0.25,
        rounds: 12,
        eval_every: 0,
        seed: 9,
        threads,
        ..RunConfig::default()
    };
    let problem = Arc::new(mlp_problem(61));
    let run = |threads: usize| {
        let mut s = Session::builder(problem.clone(), Arc::new(Aquila::new(0.25)))
            .config(cfg(threads))
            .build();
        let trace = s.run();
        let theta: Vec<u32> = s.theta().iter().map(|x| x.to_bits()).collect();
        (trace, theta)
    };
    let (t1, theta1) = run(1);
    for threads in [2usize, 7] {
        let (t, theta) = run(threads);
        assert_eq!(t1.total_bits(), t.total_bits(), "t={threads}");
        for (a, b) in t1.rounds.iter().zip(&t.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "t={threads} round {}",
                a.round
            );
        }
        assert_eq!(theta1, theta, "t={threads}: θ diverged bitwise");
    }
}
