//! Buffered-async engine invariants through the `Session` API: the
//! degenerate configuration (`m = K`, `staleness = constant:1`,
//! `inflight = K`) reproduces the synchronous barrier bit-exactly on
//! all three synthetic datasets, overlapping-cohort runs are
//! bit-deterministic across engine thread counts, and a checkpoint
//! taken mid-buffer (uploads still in flight) resumes to the exact
//! trace of the uninterrupted run.

use aquila::algorithms::{aquila::Aquila, qsgd::QsgdAlgo, Algorithm};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::{AggregationMode, RunConfig, Session, StalenessPolicy};
use aquila::metrics::RoundRecord;
use aquila::problems::quadratic::QuadraticProblem;
use aquila::problems::GradientSource;
use aquila::transport::scenario::NetworkSpec;
use aquila::transport::FaultSpec;
use std::sync::Arc;

/// Assert two round records agree bitwise on every deterministic
/// column (floats compared via `to_bits`).
fn assert_rounds_eq(a: &RoundRecord, b: &RoundRecord, tag: &str) {
    assert_eq!(a.round, b.round, "{tag}: round index");
    assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag} round {}", a.round);
    assert_eq!(
        a.eval_loss.map(f64::to_bits),
        b.eval_loss.map(f64::to_bits),
        "{tag} round {} eval",
        a.round
    );
    assert_eq!(a.bits_up, b.bits_up, "{tag} round {} bits_up", a.round);
    assert_eq!(a.bits_down, b.bits_down, "{tag} round {} bits_down", a.round);
    assert_eq!(a.uploads, b.uploads, "{tag} round {} uploads", a.round);
    assert_eq!(a.skips, b.skips, "{tag} round {} skips", a.round);
    assert_eq!(a.stragglers, b.stragglers, "{tag} round {} stragglers", a.round);
    assert_eq!(
        a.round_time.to_bits(),
        b.round_time.to_bits(),
        "{tag} round {} round_time",
        a.round
    );
    assert_eq!(
        a.sim_time.to_bits(),
        b.sim_time.to_bits(),
        "{tag} round {} sim_time",
        a.round
    );
    assert_eq!(
        a.mean_staleness.to_bits(),
        b.mean_staleness.to_bits(),
        "{tag} round {} mean_staleness",
        a.round
    );
    assert_eq!(a.max_staleness, b.max_staleness, "{tag} round {} max_staleness", a.round);
    assert_eq!(a.inflight, b.inflight, "{tag} round {} inflight", a.round);
}

/// The degenerate buffered configuration is the sync barrier: with
/// `m = K` (the full-participation cohort), weight-1 constant
/// staleness, and an in-flight bound that forbids overlap, the event
/// engine folds exactly one whole cohort per commit — every trace
/// column, including the simulated clock, matches the synchronous
/// path bit-for-bit on all three synthetic datasets, faults and
/// jitter included. Only the staleness/in-flight columns are compared
/// structurally (both all-zero).
#[test]
fn prop_degenerate_buffered_matches_sync_bitwise() {
    for ds in [DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, false).scaled(0.02, 8);
        let k = spec.devices;
        let run = |aggregation: AggregationMode| {
            let problem: Arc<dyn GradientSource> = spec.build_problem().into();
            let mut cfg = spec.run_config();
            cfg.threads = 2;
            cfg.network = NetworkSpec::parse("edge-mix:jitter=0.3").unwrap();
            cfg.faults = FaultSpec {
                drop_prob: 0.2,
                seed: 9,
            };
            cfg.aggregation = aggregation;
            let mut s = Session::builder(problem, Arc::new(Aquila::new(spec.beta)))
                .config(cfg)
                .build();
            let trace = s.run();
            let theta: Vec<u32> = s.theta().iter().map(|x| x.to_bits()).collect();
            (trace, theta)
        };
        let (t_sync, theta_sync) = run(AggregationMode::Sync);
        let (t_buf, theta_buf) = run(AggregationMode::Buffered {
            m: k,
            staleness: StalenessPolicy::Constant(1.0),
            max_inflight: k,
        });
        assert_eq!(t_sync.rounds.len(), t_buf.rounds.len(), "{ds:?}");
        for (a, b) in t_sync.rounds.iter().zip(&t_buf.rounds) {
            assert_rounds_eq(a, b, &format!("{ds:?}"));
            assert_eq!(b.max_staleness, 0, "{ds:?}: degenerate mode cannot be stale");
            assert_eq!(b.inflight, 0, "{ds:?}: degenerate mode cannot overlap");
        }
        assert_eq!(theta_sync, theta_buf, "{ds:?}: θ diverged bitwise");
    }
}

fn buffered_cfg(threads: usize) -> RunConfig {
    RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds: 12,
        eval_every: 3,
        seed: 85,
        threads,
        network: NetworkSpec::parse("edge-mix:jitter=0.25").unwrap(),
        faults: FaultSpec {
            drop_prob: 0.15,
            seed: 3,
        },
        aggregation: AggregationMode::Buffered {
            m: 5,
            staleness: StalenessPolicy::Poly(0.5),
            max_inflight: 24,
        },
        ..RunConfig::default()
    }
}

/// An overlapping buffered run (`m` < cohort, generous in-flight
/// bound) is bit-deterministic across engine thread counts {1, 2, 7}:
/// the event queue is ordered by `(arrival, version, device)` with
/// total-order float comparison and all per-dispatch randomness is
/// round-keyed, so thread scheduling cannot reorder folds.
#[test]
fn prop_buffered_deterministic_across_threads() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 83));
    let run = |threads: usize| {
        let mut s = Session::builder(p.clone(), Arc::new(QsgdAlgo::new(6)))
            .config(buffered_cfg(threads))
            .build();
        let trace = s.run();
        let theta: Vec<u32> = s.theta().iter().map(|x| x.to_bits()).collect();
        (trace, theta)
    };
    let (t1, theta1) = run(1);
    // The configuration must actually exercise the async machinery:
    // overlapped commits fold stale uploads.
    assert!(
        t1.rounds.iter().any(|r| r.inflight > 0),
        "no commit ever had uploads in flight — overlap never happened"
    );
    assert!(
        t1.rounds.iter().any(|r| r.max_staleness > 0),
        "no stale upload was ever folded"
    );
    let mut prev = 0.0;
    for r in &t1.rounds {
        assert!(r.sim_time >= prev, "round {}: sim_time not monotone", r.round);
        prev = r.sim_time;
    }
    for threads in [2usize, 7] {
        let (t, theta) = run(threads);
        assert_eq!(t1.rounds.len(), t.rounds.len(), "t={threads}");
        for (a, b) in t1.rounds.iter().zip(&t.rounds) {
            assert_rounds_eq(a, b, &format!("t={threads}"));
        }
        assert_eq!(theta1, theta, "t={threads}: θ diverged bitwise");
    }
}

/// A checkpoint taken mid-buffer — uploads still in flight across the
/// commit boundary — restores to the exact uninterrupted trace: the
/// v7 snapshot carries the event queue (bit-exact arrival times), the
/// partial buffer, the fold context, and the pending byte counters.
/// The snapshot is also round-tripped through the on-disk format to
/// pin the binary v7 layout, not just the in-memory struct.
#[test]
fn prop_buffered_checkpoint_resume_is_exact() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 89));
    let algo: Arc<dyn Algorithm> = Arc::new(QsgdAlgo::new(6));
    let session = || {
        Session::builder(p.clone(), algo.clone())
            .config(buffered_cfg(2))
            .build()
    };

    let mut uninterrupted = session();
    let mut full_rounds = Vec::new();
    for k in 0..12 {
        full_rounds.push(uninterrupted.run_round(k));
    }

    let mut first_half = session();
    for k in 0..6 {
        first_half.run_round(k);
    }
    let ckpt = first_half.snapshot(6);
    let state = ckpt.async_state.as_ref().expect("buffered runs snapshot async state");
    assert!(
        !state.events.is_empty() || !state.buffer.is_empty(),
        "checkpoint boundary was not mid-buffer — nothing in flight"
    );

    // Round-trip through the on-disk v7 format.
    let path = std::env::temp_dir().join(format!("aquila_async_ckpt_{}.bin", std::process::id()));
    ckpt.save(&path).expect("save v7 checkpoint");
    let loaded = aquila::coordinator::checkpoint::Checkpoint::load(&path).expect("load v7");
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded.async_state, ckpt.async_state, "v7 async section round-trip");

    let mut resumed = session();
    let next = resumed.restore(&loaded).unwrap();
    assert_eq!(next, 6);
    for k in 6..12 {
        let r = resumed.run_round(k);
        assert_rounds_eq(&full_rounds[k], &r, "resumed");
    }
    assert_eq!(resumed.theta(), uninterrupted.theta());
    assert_eq!(resumed.total_bits(), uninterrupted.total_bits());
    assert_eq!(
        resumed.total_sim_time().to_bits(),
        uninterrupted.total_sim_time().to_bits()
    );
}
