//! Integration tests across the L3↔L2/L1 bridge: load the AOT HLO
//! artifacts through PJRT and validate numerics against the Rust
//! implementations.
//!
//! These tests are skipped (with a note) when `artifacts/` has not been
//! built — run `make artifacts` first. CI runs them after the AOT step.
//! The whole file needs the `xla` feature (PJRT bindings).
#![cfg(feature = "xla")]

use aquila::data::text::{markov_corpus, shard_corpus, CorpusSpec};
use aquila::problems::GradientSource;
use aquila::quant::levels::aquila_level;
use aquila::quant::midtread::quantize_innovation_fused;
use aquila::runtime::{HloGradientSource, HloQuantKernel, Manifest, PjrtRuntime};
use aquila::util::rng::Xoshiro256pp;
use aquila::util::vecmath::innovation_norms;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn random_vec(d: usize, seed: u64, std: f32) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..d).map(|_| rng.gaussian_f32(0.0, std)).collect()
}

#[test]
fn manifest_loads_and_references_existing_files() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(!m.models.is_empty());
    for model in &m.models {
        assert!(model.grad_file.exists(), "{:?}", model.grad_file);
        assert!(model.eval_file.exists());
        assert!(model.dim > 0);
        assert_eq!(model.layout.dim(), model.dim);
        if let Some(step) = &model.step_file {
            assert!(step.exists());
        }
    }
    for k in &m.kernels {
        assert!(k.file.exists());
    }
}

#[test]
fn grad_artifact_executes_and_loss_is_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let model = m.model("txf_tiny").unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let corpus = markov_corpus(&CorpusSpec::wikitext2_like(20_000, 3));
    let shards = shard_corpus(&corpus.slice(2000, corpus.len()), 4);
    let heldout = corpus.slice(0, 2000);
    let src = HloGradientSource::new(&runtime, model, &shards, &heldout).unwrap();
    assert_eq!(src.dim(), model.dim);
    assert_eq!(src.num_devices(), 4);

    let theta = src.init_theta(1);
    let mut ws = src.make_scratch();
    let mut grad = vec![0.0f32; src.dim()];
    let loss = src.local_grad(0, &theta, &mut grad, &mut ws);
    // Near-random init ⇒ loss ≈ ln(vocab) = ln 64 ≈ 4.16.
    assert!(
        (loss - (model.vocab as f64).ln()).abs() < 1.0,
        "loss {loss} far from ln(V) = {}",
        (model.vocab as f64).ln()
    );
    let gnorm = aquila::util::vecmath::norm2(&grad);
    assert!(gnorm > 1e-3 && gnorm.is_finite(), "grad norm {gnorm}");

    // One gradient step lowers the local loss.
    let mut theta2 = theta.clone();
    aquila::util::vecmath::axpy(-0.5, &grad, &mut theta2);
    let mut g2 = vec![0.0f32; src.dim()];
    let loss2 = src.local_grad(0, &theta2, &mut g2, &mut ws);
    assert!(loss2 < loss, "descent failed: {loss} -> {loss2}");

    // Eval reports perplexity = exp(loss).
    let ev = src.eval(&theta);
    let ppl = ev.perplexity.unwrap();
    assert!((ppl - ev.loss.exp()).abs() < 1e-6);
    assert!(ppl > 1.0 && ppl < 2.0 * model.vocab as f64);
}

#[test]
fn pallas_kernel_parity_with_rust_hot_path() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let entry = &m.kernels[0];
    let runtime = PjrtRuntime::cpu().unwrap();
    let kernel = HloQuantKernel::load(&runtime, entry).unwrap();
    let d = kernel.dim;
    for seed in 0..3u64 {
        let g = random_vec(d, 100 + seed, 1.0);
        let q = random_vec(d, 200 + seed, 0.8);
        let hlo = kernel.run(&g, &q).unwrap();

        // Rust-native fused step.
        let (l2sq, linf) = innovation_norms(&g, &q);
        let bits = aquila_level(l2sq.sqrt(), linf, d);
        let mut dq = vec![0.0f32; d];
        let out = quantize_innovation_fused(&g, &q, bits, linf, &mut dq);

        assert_eq!(hlo.bits, bits, "level rule parity (seed {seed})");
        assert!((hlo.range - linf).abs() <= f32::EPSILON * linf.abs());
        for (i, (a, b)) in hlo.dq.iter().zip(&dq).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * linf.abs().max(1.0),
                "dq[{i}]: HLO {a} vs rust {b}"
            );
        }
        let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1e-9);
        assert!(rel(hlo.dq_norm_sq, out.dq_norm_sq) < 1e-3);
        assert!(rel(hlo.err_norm_sq, out.err_norm_sq) < 2e-2);
    }
}

#[test]
fn zero_innovation_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let kernel = HloQuantKernel::load(&runtime, &m.kernels[0]).unwrap();
    let g = random_vec(kernel.dim, 7, 0.5);
    let hlo = kernel.run(&g, &g).unwrap();
    assert_eq!(hlo.bits, 1);
    assert_eq!(hlo.range, 0.0);
    assert!(hlo.dq.iter().all(|&x| x == 0.0));
    assert_eq!(hlo.dq_norm_sq, 0.0);
    assert_eq!(hlo.err_norm_sq, 0.0);
}

#[test]
fn hlo_source_runs_a_federated_round() {
    use aquila::algorithms::aquila::Aquila;
    use aquila::coordinator::{RunConfig, Session};
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let model = m.model("txf_tiny").unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let corpus = markov_corpus(&CorpusSpec::wikitext2_like(30_000, 5));
    let shards = shard_corpus(&corpus.slice(3000, corpus.len()), 4);
    let heldout = corpus.slice(0, 3000);
    let src = Arc::new(HloGradientSource::new(&runtime, model, &shards, &heldout).unwrap());
    let cfg = RunConfig {
        alpha: 0.5,
        beta: 1.25,
        rounds: 5,
        eval_every: 0,
        seed: 11,
        threads: 2,
        ..RunConfig::default()
    };
    let trace = Session::builder(src, Arc::new(Aquila::new(1.25)))
        .config(cfg)
        .dataset("wt2-hlo")
        .split("iid")
        .build()
        .run();
    assert_eq!(trace.rounds.len(), 5);
    assert!(trace.total_bits() > 0);
    // Loss must move downward over 5 rounds of full-batch descent.
    assert!(
        trace.final_train_loss() < trace.rounds[0].train_loss,
        "{} -> {}",
        trace.rounds[0].train_loss,
        trace.final_train_loss()
    );
}
