//! Population-virtualization equivalence properties (DESIGN.md
//! §Population): the lazy, spec-backed device store must be
//! *bit-identical* to the eager pre-virtualization path — across every
//! selection strategy, thread count, capacity-mask shape, cache bound
//! (including caches tiny enough to force mid-run eviction and
//! rematerialization), and checkpoint interruption — while keeping
//! resident slot counts bounded by the cache at million-device
//! populations.

use aquila::algorithms::{aquila::Aquila, qsgd::QsgdAlgo, Algorithm};
use aquila::coordinator::checkpoint::{self, Checkpoint};
use aquila::coordinator::{RunConfig, Session, SlotPolicy};
use aquila::hetero::half_half_masks;
use aquila::metrics::RoundRecord;
use aquila::problems::quadratic::{QuadraticProblem, StreamedQuadratic};
use aquila::problems::GradientSource;
use aquila::selection::{
    DeviceStats, DeviceView, LossWeighted, RandomK, Selection, SelectionSpec, SelectionStrategy,
    SelectionView,
};
use std::sync::Arc;

fn cfg(seed: u64, rounds: usize, threads: usize, slots: SlotPolicy) -> RunConfig {
    RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds,
        eval_every: 4,
        seed,
        threads,
        slots,
        ..RunConfig::default()
    }
}

fn build(
    p: &Arc<dyn GradientSource>,
    algo: Arc<dyn Algorithm>,
    spec: &SelectionSpec,
    hetero: bool,
    cfg: RunConfig,
) -> Session {
    let mut b = Session::builder(p.clone(), algo)
        .config(cfg)
        .selection_spec(spec.clone());
    if hetero {
        b = b.masks(half_half_masks(&p.layout(), p.num_devices(), 0.5));
    }
    b.build()
}

fn theta_bits(s: &Session) -> Vec<u32> {
    s.theta().iter().map(|x| x.to_bits()).collect()
}

/// Field-by-field bitwise comparison of round records (`RoundRecord`
/// deliberately has no `PartialEq` — float fields must be compared as
/// bits, not approximately).
fn assert_rounds_identical(a: &[RoundRecord], b: &[RoundRecord], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: round count");
    for (x, y) in a.iter().zip(b) {
        let k = x.round;
        assert_eq!(x.round, y.round, "{tag} round {k}: index");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{tag} round {k}: train_loss"
        );
        assert_eq!(x.bits_up, y.bits_up, "{tag} round {k}: bits_up");
        assert_eq!(x.cum_bits, y.cum_bits, "{tag} round {k}: cum_bits");
        assert_eq!(x.uploads, y.uploads, "{tag} round {k}: uploads");
        assert_eq!(x.skips, y.skips, "{tag} round {k}: skips");
        assert_eq!(
            x.mean_level.to_bits(),
            y.mean_level.to_bits(),
            "{tag} round {k}: mean_level"
        );
        assert_eq!(
            x.eval_loss.map(f64::to_bits),
            y.eval_loss.map(f64::to_bits),
            "{tag} round {k}: eval_loss"
        );
        assert_eq!(x.bits_down, y.bits_down, "{tag} round {k}: bits_down");
        assert_eq!(x.stragglers, y.stragglers, "{tag} round {k}: stragglers");
    }
}

fn strategy_specs() -> Vec<SelectionSpec> {
    vec![
        SelectionSpec::Full,
        SelectionSpec::RandomK(3),
        SelectionSpec::RoundRobin(2),
        SelectionSpec::LossWeighted(3),
        SelectionSpec::Availability {
            period: 4,
            duty: 3,
            cap: Some(3),
        },
    ]
}

/// The tentpole invariant: a lazily-materialized run is bit-identical
/// to the eager path — for every shipped selection strategy, across
/// thread counts 1/2/7, uniform and half-half capacity masks, and
/// unbounded / roomy / tight slot caches.
#[test]
fn prop_lazy_matches_eager_across_strategies_threads_masks() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 41));
    for spec in strategy_specs() {
        for hetero in [false, true] {
            let mut base = build(
                &p,
                Arc::new(Aquila::new(0.25)),
                &spec,
                hetero,
                cfg(43, 12, 1, SlotPolicy::Eager),
            );
            let base_trace = base.run();
            let base_theta = theta_bits(&base);
            let base_stats = base.device_stats();
            for threads in [1usize, 2, 7] {
                for cache in [0usize, 5, 2] {
                    let tag = format!("{spec} hetero={hetero} t={threads} cache={cache}");
                    let mut s = build(
                        &p,
                        Arc::new(Aquila::new(0.25)),
                        &spec,
                        hetero,
                        cfg(43, 12, threads, SlotPolicy::Lazy { cache }),
                    );
                    let t = s.run();
                    assert_rounds_identical(&base_trace.rounds, &t.rounds, &tag);
                    assert_eq!(base_theta, theta_bits(&s), "{tag}: θ diverged bitwise");
                    assert_eq!(base_stats, s.device_stats(), "{tag}: device stats diverged");
                }
            }
        }
    }
}

/// A cache far smaller than the population forces every round to evict
/// and rematerialize slots mid-run; the rebuilt slots must resume the
/// parked algorithm state (`q_prev`, error norms, QSGD RNG stream) so
/// traces and the model stay byte-identical to the unbounded cache —
/// no stale state leaks, no RNG desync. QSGD pins the stochastic
/// quantizer's RNG lockstep; AQUILA pins the lazy-family `dq`/loss
/// carry-over.
#[test]
fn prop_tiny_cache_eviction_rematerializes_identically() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(20, 4, 0.5, 2.0, 0.5, 47));
    let algos: Vec<Arc<dyn Algorithm>> =
        vec![Arc::new(QsgdAlgo::new(6)), Arc::new(Aquila::new(0.25))];
    for algo in &algos {
        let name = algo.name();
        let mut unbounded = build(
            &p,
            algo.clone(),
            &SelectionSpec::Full,
            false,
            cfg(49, 10, 2, SlotPolicy::Lazy { cache: 0 }),
        );
        let t_unbounded = unbounded.run();
        for cache in [1usize, 2] {
            let tag = format!("{name} cache={cache}");
            let mut s = build(
                &p,
                algo.clone(),
                &SelectionSpec::Full,
                false,
                cfg(49, 10, 2, SlotPolicy::Lazy { cache }),
            );
            let t = s.run();
            assert_rounds_identical(&t_unbounded.rounds, &t.rounds, &tag);
            assert_eq!(
                theta_bits(&unbounded),
                theta_bits(&s),
                "{tag}: θ diverged bitwise"
            );
            assert_eq!(unbounded.device_stats(), s.device_stats(), "{tag}: stats");
            // The bound held: after a round the live cache is trimmed
            // to capacity, and mid-round residency never exceeded
            // cache + cohort.
            assert!(s.resident_slots() <= cache, "{tag}: {} live", s.resident_slots());
            assert!(
                s.peak_resident_slots() <= cache + p.num_devices(),
                "{tag}: peak {}",
                s.peak_resident_slots()
            );
        }
    }
}

/// Random cohorts revisit evicted devices across a longer horizon:
/// every revisit must rebuild exactly the state the device was parked
/// with (the LRU churn path, as opposed to the every-round eviction
/// above).
#[test]
fn prop_random_revisit_after_eviction_is_exact() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(16, 6, 0.5, 2.0, 0.5, 51));
    let algo: Arc<dyn Algorithm> = Arc::new(QsgdAlgo::new(6));
    let spec = SelectionSpec::RandomK(2);
    let mut unbounded = build(
        &p,
        algo.clone(),
        &spec,
        false,
        cfg(53, 24, 3, SlotPolicy::Lazy { cache: 0 }),
    );
    let t_unbounded = unbounded.run();
    let mut tight = build(
        &p,
        algo,
        &spec,
        false,
        cfg(53, 24, 3, SlotPolicy::Lazy { cache: 2 }),
    );
    let t_tight = tight.run();
    assert_rounds_identical(&t_unbounded.rounds, &t_tight.rounds, "qsgd revisit");
    assert_eq!(theta_bits(&unbounded), theta_bits(&tight));
    assert_eq!(unbounded.device_stats(), tight.device_stats());
}

/// Checkpoint v6 round-trip under virtualization: interrupting a lazy
/// run mid-sequence, saving to disk, and restoring into a fresh
/// session — lazy *or* eager — reproduces the uninterrupted trace
/// bit-for-bit.
#[test]
fn prop_virtualized_checkpoint_resume_is_exact() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 55));
    let algo: Arc<dyn Algorithm> = Arc::new(Aquila::new(0.25));
    let spec = SelectionSpec::RandomK(3);
    let lazy = SlotPolicy::Lazy { cache: 3 };

    let mut full = build(&p, algo.clone(), &spec, false, cfg(57, 16, 2, lazy));
    let mut full_rounds = Vec::new();
    for k in 0..16 {
        full_rounds.push(full.run_round(k));
    }

    let mut first = build(&p, algo.clone(), &spec, false, cfg(57, 16, 2, lazy));
    for k in 0..8 {
        first.run_round(k);
    }
    let ckpt = first.snapshot(8);
    assert_eq!(ckpt.version, checkpoint::VERSION);
    assert_eq!(ckpt.population, 8);
    assert!(
        ckpt.device_ids.windows(2).all(|w| w[0] < w[1]),
        "tracked ids must be sorted: {:?}",
        ckpt.device_ids
    );

    let dir = std::env::temp_dir().join("aquila_pop_ckpt");
    let path = dir.join("t.ckpt");
    ckpt.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(loaded.device_ids, ckpt.device_ids);

    for policy in [lazy, SlotPolicy::Eager] {
        let mut resumed = build(&p, algo.clone(), &spec, false, cfg(57, 16, 2, policy));
        let next = resumed.restore(&loaded).unwrap();
        assert_eq!(next, 8);
        for k in 8..16 {
            let rec = resumed.run_round(k);
            let f = &full_rounds[k];
            assert_eq!(
                rec.train_loss.to_bits(),
                f.train_loss.to_bits(),
                "{policy:?} round {k}: loss diverged after resume"
            );
            assert_eq!(rec.bits_up, f.bits_up, "{policy:?} round {k}: bits");
            assert_eq!(rec.uploads, f.uploads, "{policy:?} round {k}: cohort");
            assert_eq!(rec.skips, f.skips, "{policy:?} round {k}: skips");
        }
        assert_eq!(theta_bits(&resumed), theta_bits(&full), "{policy:?}: θ diverged");
    }
}

/// The dense→sparse migration direction: a checkpoint taken from an
/// eager (all-devices-tracked) run restores into a lazy session and
/// continues identically — old dense snapshots keep working after the
/// population redesign.
#[test]
fn prop_eager_checkpoint_restores_into_lazy_session() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(20, 6, 0.5, 2.0, 0.5, 59));
    let algo: Arc<dyn Algorithm> = Arc::new(QsgdAlgo::new(6));
    let spec = SelectionSpec::RoundRobin(2);

    let mut full = build(&p, algo.clone(), &spec, false, cfg(61, 14, 2, SlotPolicy::Eager));
    let mut full_rounds = Vec::new();
    for k in 0..14 {
        full_rounds.push(full.run_round(k));
    }

    let mut first = build(&p, algo.clone(), &spec, false, cfg(61, 14, 2, SlotPolicy::Eager));
    for k in 0..7 {
        first.run_round(k);
    }
    let ckpt = first.snapshot(7);
    // Eager tracks the whole population, like pre-v6 dense snapshots.
    assert_eq!(ckpt.device_ids, (0..6).collect::<Vec<_>>());

    let mut resumed = build(
        &p,
        algo,
        &spec,
        false,
        cfg(61, 14, 2, SlotPolicy::Lazy { cache: 2 }),
    );
    assert_eq!(resumed.restore(&ckpt).unwrap(), 7);
    for k in 7..14 {
        let rec = resumed.run_round(k);
        let f = &full_rounds[k];
        assert_eq!(rec.train_loss.to_bits(), f.train_loss.to_bits(), "round {k}");
        assert_eq!(rec.bits_up, f.bits_up, "round {k}");
    }
    assert_eq!(theta_bits(&resumed), theta_bits(&full));
}

/// Lazy checkpoints are sparse: only devices that ever materialized
/// are tracked, the header still records the full population size, and
/// the sparse snapshot resumes exactly.
#[test]
fn prop_lazy_checkpoint_tracks_only_touched_devices() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(16, 12, 0.5, 2.0, 0.5, 63));
    let algo: Arc<dyn Algorithm> = Arc::new(Aquila::new(0.25));
    let spec = SelectionSpec::RandomK(2);
    let lazy = SlotPolicy::Lazy { cache: 2 };

    let mut full = build(&p, algo.clone(), &spec, false, cfg(65, 8, 1, lazy));
    let mut full_rounds = Vec::new();
    for k in 0..8 {
        full_rounds.push(full.run_round(k));
    }

    let mut first = build(&p, algo.clone(), &spec, false, cfg(65, 8, 1, lazy));
    for k in 0..4 {
        first.run_round(k);
    }
    let ckpt = first.snapshot(4);
    assert_eq!(ckpt.population, 12);
    // 4 rounds × K=2 touch at most 8 of the 12 devices.
    assert!(
        ckpt.device_ids.len() <= 8,
        "tracked {} devices",
        ckpt.device_ids.len()
    );
    assert!(ckpt.device_ids.iter().all(|&id| id < 12));

    let mut resumed = build(&p, algo, &spec, false, cfg(65, 8, 1, lazy));
    assert_eq!(resumed.restore(&ckpt).unwrap(), 4);
    for k in 4..8 {
        let rec = resumed.run_round(k);
        let f = &full_rounds[k];
        assert_eq!(rec.train_loss.to_bits(), f.train_loss.to_bits(), "round {k}");
        assert_eq!(rec.bits_up, f.bits_up, "round {k}");
    }
    assert_eq!(theta_bits(&resumed), theta_bits(&full));
}

/// The dense `device_stats()` reconstruction of the sparse per-device
/// map: untouched devices read as the documented default (zero
/// uploads, zero skips) and the participation totals balance.
#[test]
fn prop_dense_stats_reconstruction_defaults_unseen() {
    let p: Arc<dyn GradientSource> = Arc::new(QuadraticProblem::new(16, 10, 0.5, 2.0, 0.5, 67));
    let mut s = build(
        &p,
        Arc::new(Aquila::new(0.25)),
        &SelectionSpec::RandomK(3),
        false,
        cfg(69, 5, 2, SlotPolicy::Lazy { cache: 3 }),
    );
    let trace = s.run();
    let dense = s.device_stats();
    assert_eq!(dense.len(), 10, "dense reconstruction covers the population");
    let participants: u64 = trace
        .rounds
        .iter()
        .map(|r| (r.uploads + r.skips) as u64)
        .sum();
    assert_eq!(
        dense.iter().map(|&(u, sk)| u + sk).sum::<u64>(),
        participants,
        "participation totals must balance"
    );
    // 5 rounds × K=3 touch at most 15 slots over 10 devices; at least
    // 10 - 15 < 10 means some device may remain untouched — whichever
    // are untouched must read exactly (0, 0).
    for (id, &(u, sk)) in dense.iter().enumerate() {
        assert!(u + sk <= 5, "device {id} participated {} times in 5 rounds", u + sk);
    }
}

/// Strategies read identical statistics through the sparse map and its
/// dense padding: cohorts match round for round on the overlap, and at
/// a million-device population the O(K) samplers still produce
/// exact-size, distinct, in-range cohorts.
#[test]
fn prop_selection_sparse_equals_dense_and_scales_to_millions() {
    let observed = [(3usize, 2.5f64, 4u64), (17, 0.7, 2), (40, 9.0, 1)];
    let mut sparse = DeviceStats::new();
    let mut dense = vec![DeviceView::default(); 64];
    for &(id, loss, ups) in &observed {
        let v = DeviceView {
            uploads: ups,
            skips: 1,
            last_loss: Some(loss),
        };
        sparse.insert(id, v.clone());
        dense[id] = v;
    }
    let dense = DeviceStats::from_dense(&dense);
    // Equality on the overlap, for the stats-driven strategy.
    for k in [1usize, 5, 16] {
        let mut a = LossWeighted::new(k, 7);
        let mut b = LossWeighted::new(k, 7);
        for round in 0..30 {
            let sa = {
                let v = SelectionView {
                    round,
                    num_devices: 64,
                    stats: &sparse,
                    init_loss: 1.0,
                    prev_loss: 1.0,
                    loss_history: &[],
                };
                a.select(&v)
            };
            let sb = {
                let v = SelectionView {
                    round,
                    num_devices: 64,
                    stats: &dense,
                    init_loss: 1.0,
                    prev_loss: 1.0,
                    loss_history: &[],
                };
                b.select(&v)
            };
            assert_eq!(sa, sb, "k={k} round {round}: sparse vs dense cohorts");
        }
    }
    // Million-device scaling: exact-size, distinct, in-range cohorts
    // without touching O(population) state.
    let m = 1_000_000usize;
    let mut lw = LossWeighted::new(1000, 9);
    let mut rk = RandomK::new(1000, 9);
    for round in 0..3 {
        let v = SelectionView {
            round,
            num_devices: m,
            stats: &sparse,
            init_loss: 1.0,
            prev_loss: 1.0,
            loss_history: &[],
        };
        for (name, sel) in [("loss-weighted", lw.select(&v)), ("random-k", rk.select(&v))] {
            let Selection::Devices(mut ids) = sel else {
                panic!("{name} must return an explicit cohort");
            };
            assert_eq!(ids.len(), 1000, "{name} round {round}");
            assert!(ids.iter().all(|&i| i < m), "{name} round {round}: out of range");
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 1000, "{name} round {round}: duplicates");
        }
    }
}

/// The streamed (virtualizable) problem behaves like any other
/// `GradientSource`: lazy and eager runs over it agree bitwise.
#[test]
fn prop_streamed_problem_lazy_matches_eager() {
    let p: Arc<dyn GradientSource> = Arc::new(StreamedQuadratic::new(16, 40, 0.5, 2.0, 0.5, 71));
    let algos: Vec<Arc<dyn Algorithm>> =
        vec![Arc::new(QsgdAlgo::new(6)), Arc::new(Aquila::new(0.25))];
    for algo in &algos {
        let name = algo.name();
        let spec = SelectionSpec::RandomK(8);
        let mut eager = build(&p, algo.clone(), &spec, false, cfg(73, 10, 2, SlotPolicy::Eager));
        let t_eager = eager.run();
        let mut lazy = build(
            &p,
            algo.clone(),
            &spec,
            false,
            cfg(73, 10, 7, SlotPolicy::Lazy { cache: 3 }),
        );
        let t_lazy = lazy.run();
        assert_rounds_identical(&t_eager.rounds, &t_lazy.rounds, name);
        assert_eq!(theta_bits(&eager), theta_bits(&lazy), "{name}: θ diverged");
    }
}

/// A seeded million-device virtualized round sequence completes with
/// resident slots bounded by the cache (+ in-flight cohort) — the
/// memory contract behind `benches/population.rs`.
#[test]
fn prop_million_device_session_is_bounded() {
    let m = 1_000_000usize;
    let cache = 2048usize;
    let p: Arc<dyn GradientSource> = Arc::new(StreamedQuadratic::new(64, m, 0.5, 2.0, 0.5, 75));
    let run_cfg = RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds: 1000,
        eval_every: 0,
        seed: 77,
        threads: 4,
        slots: SlotPolicy::Lazy { cache },
        ..RunConfig::default()
    };
    let mut s = Session::builder(p, Arc::new(Aquila::new(0.25)))
        .config(run_cfg)
        .selection_spec(SelectionSpec::RandomK(1000))
        .build();
    for k in 0..3 {
        let rec = s.run_round(k);
        assert!(rec.uploads + rec.skips <= 1000, "round {k} cohort too big");
        assert!(rec.train_loss.is_finite(), "round {k} loss not finite");
        assert!(
            s.resident_slots() <= cache,
            "round {k}: {} live slots exceed the cache",
            s.resident_slots()
        );
    }
    assert!(
        s.peak_resident_slots() <= cache + 1000,
        "peak residency {} exceeds cache + cohort",
        s.peak_resident_slots()
    );
    assert!(s.total_bits() > 0);
}
