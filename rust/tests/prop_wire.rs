//! Hardened wire decoding (ISSUE 5): randomized corruption of valid
//! encodings — truncation, bad tags, out-of-range bits, oversized
//! length fields, malformed v2 section tables, short bodies, and
//! arbitrary byte flips. Every malformed buffer must come back as a
//! `WireError`; `decode`/`view` must never panic or over-read.

use aquila::quant::midtread::{quantize, quantize_sections};
use aquila::quant::qsgd;
use aquila::quant::Sections;
use aquila::transport::wire::{decode, encode, view, Payload, WireError};
use aquila::util::rng::Xoshiro256pp;

fn random_vec(rng: &mut Xoshiro256pp, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.gaussian_f32(0.0, 1.5)).collect()
}

/// One payload of every wire form (v1 global and v2 sectioned).
fn payload_suite(rng: &mut Xoshiro256pp, d: usize) -> Vec<Payload> {
    let v = random_vec(rng, d);
    let sections = Sections::from_lens([d / 3, d / 4, d - d / 3 - d / 4]);
    vec![
        Payload::MidtreadDelta(quantize(&v, 4)),
        Payload::MidtreadFull(quantize(&v, 9)),
        Payload::Qsgd(qsgd::quantize(&v, 5, rng)),
        Payload::RawDelta(v.clone()),
        Payload::RawFull(v.clone()),
        Payload::MidtreadDelta(quantize_sections(&v, 4, &sections)),
        Payload::MidtreadFull(quantize_sections(&v, 11, &sections)),
        Payload::Qsgd(qsgd::quantize_sections(&v, 6, &sections, rng)),
    ]
}

/// Every strict prefix of a valid encoding is rejected; the full
/// buffer round-trips.
#[test]
fn prop_truncation_always_rejected() {
    let mut rng = Xoshiro256pp::seed_from_u64(7100);
    for d in [24usize, 97, 256] {
        for p in payload_suite(&mut rng, d) {
            let enc = encode(&p);
            assert_eq!(decode(&enc).unwrap(), p);
            // Every prefix length, not just a sample: truncation must
            // never parse (the body length is exact, so any strict
            // prefix is short).
            for cut in 0..enc.len() {
                let pre = &enc[..cut];
                assert!(decode(pre).is_err(), "prefix {cut}/{} parsed", enc.len());
                assert!(view(pre).is_err());
            }
        }
    }
}

/// Unknown tag bytes are rejected with `UnknownTag`.
#[test]
fn prop_unknown_tags_rejected() {
    let mut rng = Xoshiro256pp::seed_from_u64(7101);
    let enc = encode(&payload_suite(&mut rng, 64).remove(0));
    for tag in [0u8, 9, 10, 42, 127, 200, 255] {
        let mut bad = enc.clone();
        bad[0] = tag;
        match decode(&bad) {
            Err(WireError::UnknownTag(t)) => assert_eq!(t, tag),
            other => panic!("tag {tag}: expected UnknownTag, got {other:?}"),
        }
    }
}

/// Out-of-range bits fields are rejected for every quantized form.
#[test]
fn prop_bad_bits_rejected() {
    let mut rng = Xoshiro256pp::seed_from_u64(7102);
    for p in payload_suite(&mut rng, 48) {
        let enc = encode(&p);
        let quantized = !matches!(p, Payload::RawDelta(_) | Payload::RawFull(_));
        if !quantized {
            continue;
        }
        for bits in [0u8, 33, 64, 255] {
            let mut bad = enc.clone();
            bad[1] = bits;
            assert!(
                matches!(decode(&bad), Err(WireError::BadBits(_))),
                "bits={bits} accepted for {p:?}"
            );
        }
        // 32 magnitude bits are invalid for QSGD specifically.
        if matches!(p, Payload::Qsgd(_)) {
            let mut bad = enc.clone();
            bad[1] = 32;
            assert!(matches!(decode(&bad), Err(WireError::BadBits(32))));
        }
    }
}

/// Oversized length fields (v1 len and v2 per-section lens) make the
/// body requirement exceed the buffer: rejected, never over-read.
#[test]
fn prop_oversized_len_rejected() {
    let mut rng = Xoshiro256pp::seed_from_u64(7103);
    for p in payload_suite(&mut rng, 80) {
        let enc = encode(&p);
        let sectioned = matches!(
            &p,
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) if q.is_sectioned()
        ) || matches!(&p, Payload::Qsgd(q) if q.is_sectioned());
        let mut bad = enc.clone();
        if sectioned {
            // First section's len field lives at [8..12].
            bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        } else {
            // v1 len field lives at [6..10].
            bad[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(decode(&bad).is_err(), "oversized len parsed for {p:?}");
        assert!(view(&bad).is_err());
    }
}

/// Malformed v2 section tables: zero count, zero-length sections,
/// truncated tables, non-finite scales.
#[test]
fn prop_bad_section_tables_rejected() {
    let mut rng = Xoshiro256pp::seed_from_u64(7104);
    let v = random_vec(&mut rng, 60);
    let sections = Sections::from_lens([20usize, 20, 20]);
    let enc = encode(&Payload::MidtreadFull(quantize_sections(&v, 6, &sections)));
    // Zero section count.
    let mut bad = enc.clone();
    bad[2] = 0;
    bad[3] = 0;
    assert!(matches!(decode(&bad), Err(WireError::BadSections(_))));
    // Zero-length middle section (count > 1).
    let mut bad = enc.clone();
    bad[16..20].copy_from_slice(&0u32.to_le_bytes());
    assert!(decode(&bad).is_err());
    // Count larger than the table actually present.
    let mut bad = enc.clone();
    bad[2..4].copy_from_slice(&u16::MAX.to_le_bytes());
    assert!(matches!(decode(&bad), Err(WireError::Truncated { .. })));
    // NaN / negative / infinite scales.
    for scale in [f32::NAN, f32::INFINITY, -1.0f32] {
        let mut bad = enc.clone();
        bad[4..8].copy_from_slice(&scale.to_le_bytes());
        assert!(
            matches!(decode(&bad), Err(WireError::BadSections(_))),
            "scale {scale} accepted"
        );
    }
}

/// Arbitrary single-byte flips and random buffers must never panic —
/// they either decode to *something* or return an error, but the
/// decoder must not over-read or crash.
#[test]
fn prop_random_corruption_never_panics() {
    let mut rng = Xoshiro256pp::seed_from_u64(7105);
    for d in [16usize, 130] {
        for p in payload_suite(&mut rng, d) {
            let enc = encode(&p);
            for _ in 0..300 {
                let mut bad = enc.clone();
                let i = rng.next_bounded(bad.len() as u64) as usize;
                bad[i] ^= 1 << (rng.next_bounded(8) as u32);
                // Must return, not panic; a successful decode is fine
                // (the flip may have landed in a scale or code).
                let _ = decode(&bad);
                let _ = view(&bad);
            }
        }
    }
    // Fully random buffers of many lengths.
    for len in 0..200usize {
        let buf: Vec<u8> = (0..len).map(|_| rng.next_bounded(256) as u8).collect();
        let _ = decode(&buf);
        let _ = view(&buf);
    }
}
