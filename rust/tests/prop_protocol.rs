//! Protocol conformance suite (ISSUE 6 satellite): the coordinator
//! survives malformed, truncated, and out-of-order traffic without
//! panicking; heartbeat expiry maps silent clients onto stragglers;
//! and a seeded run served over loopback or TCP reproduces the
//! in-process `RunTrace` bit for bit.
//!
//! The chaos matrix (ISSUE 7) extends the determinism acceptance to
//! faulted runs: every injected fault kind, over both transports, must
//! recover inside the round deadline and leave the trace bit-identical
//! to the fault-free in-process run; and a coordinator killed
//! mid-horizon must resume from its checkpoint with the remaining
//! rounds bit-identical to the uninterrupted run.

use aquila::algorithms::aquila::Aquila;
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::checkpoint::Checkpoint;
use aquila::coordinator::Session;
use aquila::metrics::RunTrace;
use aquila::problems::GradientSource;
use aquila::protocol::frame::{decode_frame, encode_frame, FrameReader};
use aquila::protocol::messages::{kind, RoundResult};
use aquila::protocol::transport::LoopbackDialer;
use aquila::protocol::{ChaosSpec, TcpDialer};
use aquila::protocol::{
    ClientReport, Connection, CoordinatorService, CoordinatorState, DeviceClient, Frame,
    LoopbackHub, Message, ProtocolError, ServeSpec, TcpConnection, TcpTransport,
    PROTOCOL_VERSION,
};
use aquila::repro;
use aquila::util::rng::Xoshiro256pp;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

fn tiny_on(ds: DatasetKind, rounds: usize) -> ExperimentSpec {
    let base = ExperimentSpec::new(ds, SplitKind::Iid, false);
    let mut s = base.scaled(0.02, rounds);
    s.devices = 4;
    s
}

fn tiny(rounds: usize) -> ExperimentSpec {
    tiny_on(DatasetKind::Cf10, rounds)
}

fn serve(clients: usize) -> ServeSpec {
    ServeSpec {
        clients,
        heartbeat_ms: 25,
        heartbeat_timeout_ms: 2_000,
        round_timeout_ms: 10_000,
        accept_timeout_ms: 10_000,
        ..ServeSpec::default()
    }
}

fn session_of(spec: &ExperimentSpec) -> Session {
    repro::session_for(spec, Arc::new(Aquila::new(spec.beta))).build()
}

fn inprocess(spec: &ExperimentSpec) -> (RunTrace, Vec<u32>) {
    let mut s = session_of(spec);
    let trace = s.run();
    let theta = s.theta().iter().map(|x| x.to_bits()).collect();
    (trace, theta)
}

/// A well-behaved device client serving its assigned range over the
/// loopback hub.
fn loop_client(spec: ExperimentSpec, dialer: LoopbackDialer) -> JoinHandle<ClientReport> {
    std::thread::spawn(move || {
        let problem: Arc<dyn GradientSource> = spec.build_problem().into();
        let masks = repro::masks_for(&spec, problem.as_ref());
        let algo = Arc::new(Aquila::new(spec.beta));
        let client = DeviceClient::new(problem, algo, spec.run_config(), masks).heartbeat_ms(25);
        let mut conn = dialer.connect();
        client.run(&mut conn).expect("loopback client")
    })
}

/// The same client over a real TCP connection.
fn tcp_client(spec: ExperimentSpec, addr: String) -> JoinHandle<ClientReport> {
    std::thread::spawn(move || {
        let problem: Arc<dyn GradientSource> = spec.build_problem().into();
        let masks = repro::masks_for(&spec, problem.as_ref());
        let algo = Arc::new(Aquila::new(spec.beta));
        let client = DeviceClient::new(problem, algo, spec.run_config(), masks).heartbeat_ms(25);
        let mut conn = TcpConnection::connect(&addr, Duration::from_secs(10)).expect("connect");
        client.run(&mut conn).expect("tcp client")
    })
}

/// A fault-tolerant client: dials through the `Dial` abstraction and
/// reconnects with backoff whenever chaos kills its connection, so an
/// injected fault costs a rejoin, never the run.
fn resilient_client(spec: &ExperimentSpec) -> DeviceClient {
    repro::client_for(spec, Arc::new(Aquila::new(spec.beta)))
        .heartbeat_ms(25)
        .reconnect(40, 10, 100)
        .idle_timeout_ms(500)
}

fn resilient_loop_client(spec: ExperimentSpec, dialer: LoopbackDialer) -> JoinHandle<ClientReport> {
    std::thread::spawn(move || {
        resilient_client(&spec)
            .run_with(&dialer)
            .expect("resilient loopback client")
    })
}

fn resilient_tcp_client(spec: ExperimentSpec, addr: String) -> JoinHandle<ClientReport> {
    std::thread::spawn(move || {
        let dialer = TcpDialer::new(addr, Duration::from_secs(5));
        resilient_client(&spec)
            .run_with(&dialer)
            .expect("resilient tcp client")
    })
}

/// The codec layers are total: random bytes through `decode_frame` and
/// `Message::decode` yield typed errors, never panics, and a valid
/// multi-frame stream reassembles correctly across every chunk split.
#[test]
fn prop_codec_total_on_garbage() {
    let mut rng = Xoshiro256pp::seed_from_u64(0xF00D);
    for _ in 0..200 {
        let len = (rng.next_u64() % 64) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = decode_frame(&bytes);
        let _ = Message::decode(rng.next_u64() as u8, &bytes);
    }

    fn feed(reader: &mut FrameReader, mut rest: &[u8], frames: &mut Vec<Frame>) {
        while !rest.is_empty() {
            let take = reader.wanted().min(rest.len());
            if let Some(f) = reader.consume(&rest[..take]).expect("valid stream") {
                frames.push(f);
            }
            rest = &rest[take..];
        }
    }
    let mut stream = Vec::new();
    let mut body = Vec::new();
    Message::Heartbeat.encode_body(&mut body);
    encode_frame(kind::HEARTBEAT, &body, &mut stream);
    let rdv = Message::Rendezvous { version: PROTOCOL_VERSION, want: 3 };
    rdv.encode_body(&mut body);
    encode_frame(kind::RENDEZVOUS, &body, &mut stream);
    for split in 1..stream.len() {
        let mut reader = FrameReader::new();
        let mut frames = Vec::new();
        feed(&mut reader, &stream[..split], &mut frames);
        feed(&mut reader, &stream[split..], &mut frames);
        assert_eq!(frames.len(), 2, "split at {split}");
        assert_eq!(frames[0].kind, kind::HEARTBEAT);
        assert_eq!(frames[1].kind, kind::RENDEZVOUS);
    }
}

/// Garbage connections during standby — unknown kinds, truncated
/// bodies, a wrong-version rendezvous — are rejected without consuming
/// a device range, and the eventual run is bit-identical to the
/// in-process trace.
#[test]
fn prop_standby_garbage_does_not_perturb_run() {
    let spec = tiny(6);
    let (want, _) = inprocess(&spec);

    let mut hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let garbage = dialer.connect();
    garbage.send_raw(0xEE, vec![0xAA; 9]).expect("inject");
    garbage.send_raw(kind::ROUND_RESULT, vec![1, 2, 3]).expect("inject");
    let mut badver = dialer.connect();
    badver.send(&Message::Rendezvous { version: 0, want: 0 }).expect("inject");
    let clients: Vec<_> = (0..2).map(|_| loop_client(spec.clone(), dialer.clone())).collect();
    let mut service = CoordinatorService::new(session_of(&spec), serve(2));
    let got = service.run(&mut hub).expect("service run");
    for h in clients {
        h.join().expect("client");
    }
    drop(garbage);
    drop(badver);
    assert_eq!(
        format!("{:?}", want.rounds),
        format!("{:?}", got.rounds),
        "standby garbage perturbed the trace"
    );
}

/// An admitted hostile client that reports stale rounds, devices it
/// does not own, and out-of-range ids — but never its real assignment —
/// cannot corrupt the other clients' results. Its own devices are
/// simply stragglers and the run completes.
#[test]
fn prop_hostile_results_cannot_corrupt_other_clients() {
    let spec = tiny(3);
    let mut hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let evil = std::thread::spawn({
        let dialer = dialer.clone();
        move || {
            let mut conn = dialer.connect();
            let rdv = Message::Rendezvous { version: PROTOCOL_VERSION, want: 0 };
            conn.send(&rdv).expect("rendezvous");
            let w = match conn.recv(Duration::from_secs(10)).expect("welcome") {
                Message::Welcome(w) => w,
                other => panic!("expected welcome, got {other:?}"),
            };
            let poison = |round: u32, device: u32| {
                Message::RoundResult(RoundResult {
                    round,
                    device,
                    loss: 1.0e9,
                    level: Some(32),
                    uploads: 99,
                    skips: 99,
                    payload: None,
                })
            };
            loop {
                match conn.recv(Duration::from_millis(20)) {
                    Ok(Message::StartRound(sr)) => {
                        let k = sr.ctx.round as u32;
                        // Stale round, foreign device, out-of-range id,
                        // and an out-of-order rendezvous — all ignored.
                        conn.send(&poison(k + 1_000, w.device_lo)).expect("send");
                        conn.send(&poison(k, w.device_lo + w.device_count)).expect("send");
                        conn.send(&poison(k, 10_000)).expect("send");
                        conn.send(&rdv).expect("send");
                    }
                    Ok(Message::EndRound { state: CoordinatorState::Finished, .. }) => break,
                    Ok(_) => {}
                    Err(ProtocolError::Timeout) => {
                        if conn.send(&Message::Heartbeat).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        }
    });
    let honest = loop_client(spec.clone(), dialer);
    let mut service = CoordinatorService::new(
        session_of(&spec),
        ServeSpec { round_timeout_ms: 300, ..serve(2) },
    );
    let trace = service.run(&mut hub).expect("service run");
    evil.join().expect("evil client");
    let rep = honest.join().expect("honest client");

    assert_eq!(trace.rounds.len(), 3);
    assert_eq!(rep.rounds_served, 3);
    for r in &trace.rounds {
        assert!(r.train_loss.is_finite(), "round {}: poisoned loss", r.round);
        assert!(r.train_loss < 1.0e6, "round {}: poisoned loss folded in", r.round);
        // The hostile client's two devices miss every round's deadline.
        assert_eq!(r.stragglers, 2, "round {}", r.round);
    }
}

/// A client that goes silent (no results, no heartbeats, socket held
/// open) is detected through heartbeat expiry: its devices become
/// stragglers, the run completes the full horizon, and the healthy
/// client keeps serving.
#[test]
fn prop_heartbeat_expiry_marks_stragglers() {
    let spec = tiny(3);
    let mut hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let silent = std::thread::spawn({
        let spec = spec.clone();
        let dialer = dialer.clone();
        move || {
            let problem: Arc<dyn GradientSource> = spec.build_problem().into();
            let masks = repro::masks_for(&spec, problem.as_ref());
            let algo = Arc::new(Aquila::new(spec.beta));
            let client = DeviceClient::new(problem, algo, spec.run_config(), masks)
                .heartbeat_ms(25)
                .silent_after(1);
            let mut conn = dialer.connect();
            client.run(&mut conn).expect("silent client exits cleanly")
        }
    });
    let honest = loop_client(spec.clone(), dialer);
    // A short round timeout: the rejoin-aware collect loop waits for
    // lost devices until the deadline, and this client never comes back.
    let mut service = CoordinatorService::new(
        session_of(&spec),
        ServeSpec { heartbeat_timeout_ms: 250, round_timeout_ms: 800, ..serve(2) },
    );
    let trace = service.run(&mut hub).expect("service run");
    let silent_rep = silent.join().expect("silent client");
    let honest_rep = honest.join().expect("honest client");

    assert_eq!(trace.rounds.len(), 3);
    assert_eq!(trace.rounds[0].stragglers, 0, "round 0 is fully served");
    // The silent client's two devices miss rounds 1 and 2.
    assert_eq!(trace.total_stragglers(), 4, "heartbeat expiry must mark stragglers");
    assert_eq!(silent_rep.rounds_served, 1);
    assert_eq!(honest_rep.rounds_served, 3);
}

/// The determinism acceptance: one seeded run executed in-process,
/// served over the loopback hub, and served over real TCP — all three
/// traces (and the final model) agree bit for bit.
#[test]
fn prop_service_trace_matches_inprocess_over_both_transports() {
    let spec = tiny(5);
    let (want, theta_want) = inprocess(&spec);

    let mut hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let clients: Vec<_> = (0..2).map(|_| loop_client(spec.clone(), dialer.clone())).collect();
    let mut service = CoordinatorService::new(session_of(&spec), serve(2));
    let loopback = service.run(&mut hub).expect("loopback run");
    for h in clients {
        h.join().expect("client");
    }
    let theta_loop: Vec<u32> = service.session().theta().iter().map(|x| x.to_bits()).collect();
    assert_eq!(
        format!("{:?}", want.rounds),
        format!("{:?}", loopback.rounds),
        "loopback service diverged from the in-process run"
    );
    assert_eq!(theta_want, theta_loop, "θ diverged bitwise over loopback");

    let mut transport = TcpTransport::bind("127.0.0.1:0").expect("bind");
    let addr = transport.local_addr().expect("addr").to_string();
    let clients: Vec<_> = (0..2).map(|_| tcp_client(spec.clone(), addr.clone())).collect();
    let mut service = CoordinatorService::new(session_of(&spec), serve(2));
    let tcp = service.run(&mut transport).expect("tcp run");
    for h in clients {
        h.join().expect("client");
    }
    assert_eq!(
        format!("{:?}", loopback.rounds),
        format!("{:?}", tcp.rounds),
        "TCP service diverged from the loopback run"
    );
}

/// One chaos case per fault kind. Seeds differ so each case exercises
/// its own deterministic fault pattern.
fn chaos_cases() -> Vec<ChaosSpec> {
    [
        "drop=0.08,seed=11",
        "stall=0.3,stall_ms=5,seed=12",
        "partial=0.05,seed=13",
        "corrupt=0.05,seed=14",
        "dup=0.2,seed=15",
        "accept=0.4,seed=16",
    ]
    .iter()
    .map(|s| ChaosSpec::parse(s).expect("chaos grammar"))
    .collect()
}

/// The chaos matrix over loopback: for every fault kind, a served run
/// with a fault-injecting coordinator transport and reconnecting
/// clients produces a trace bit-identical to the fault-free in-process
/// run — every fault recovers inside the round deadline, so no device
/// result is lost, duplicated, or folded twice.
#[test]
fn prop_chaos_matrix_loopback_trace_identical() {
    let spec = tiny(4);
    let (want, _) = inprocess(&spec);
    for chaos in chaos_cases() {
        let label = chaos.to_string();
        let mut hub = LoopbackHub::new();
        let dialer = hub.dialer();
        let clients: Vec<_> =
            (0..2).map(|_| resilient_loop_client(spec.clone(), dialer.clone())).collect();
        let mut service = CoordinatorService::new(session_of(&spec), serve(2));
        let mut transport = chaos.wrap_transport(Box::new(hub));
        let got = service.run(&mut transport).expect("chaos run completes");
        for h in clients {
            h.join().expect("client");
        }
        assert_eq!(
            format!("{:?}", want.rounds),
            format!("{:?}", got.rounds),
            "chaos '{label}' diverged over loopback"
        );
    }
}

/// The same matrix over real TCP sockets.
#[test]
fn prop_chaos_matrix_tcp_trace_identical() {
    let spec = tiny(4);
    let (want, _) = inprocess(&spec);
    for chaos in chaos_cases() {
        let label = chaos.to_string();
        let tcp = TcpTransport::bind("127.0.0.1:0").expect("bind");
        let addr = tcp.local_addr().expect("addr").to_string();
        let clients: Vec<_> =
            (0..2).map(|_| resilient_tcp_client(spec.clone(), addr.clone())).collect();
        let mut service = CoordinatorService::new(session_of(&spec), serve(2));
        let mut transport = chaos.wrap_transport(Box::new(tcp));
        let got = service.run(&mut transport).expect("chaos run completes");
        for h in clients {
            h.join().expect("client");
        }
        assert_eq!(
            format!("{:?}", want.rounds),
            format!("{:?}", got.rounds),
            "chaos '{label}' diverged over TCP"
        );
    }
}

/// Kill-and-restart acceptance: a coordinator that dies right after
/// checkpointing a round is restarted with `--serve --resume`
/// semantics; the surviving clients reconnect into their original
/// slots and the stitched trace (head before the kill, tail after) is
/// bit-identical to the uninterrupted run, with zero stragglers
/// manufactured by the restart.
fn kill_and_resume_matches(ds: DatasetKind) {
    let spec = tiny_on(ds, 5);
    let (want, theta_want) = inprocess(&spec);
    let path = std::env::temp_dir().join(format!(
        "aquila_resume_{}_{}.ckpt",
        std::process::id(),
        ds.name()
    ));

    let mut hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let clients: Vec<_> =
        (0..2).map(|_| resilient_loop_client(spec.clone(), dialer.clone())).collect();
    // Phase 1: checkpoint every round, die right after round 1 — no
    // end-of-round broadcast, no teardown, exactly like a kill.
    let mut first = CoordinatorService::new(session_of(&spec), serve(2))
        .checkpoint_to(path.clone(), 1)
        .halt_after_round(1);
    let head = first.run(&mut hub).expect("halted run");
    assert_eq!(head.rounds.len(), 2, "halt_after_round(1) serves rounds 0..=1");
    drop(first);

    // Phase 2: a fresh coordinator restores the checkpoint and serves
    // the remaining horizon to the same (reconnecting) clients.
    let ckpt = Checkpoint::load(&path).expect("checkpoint readable");
    let mut second = CoordinatorService::new(session_of(&spec), serve(2));
    assert_eq!(second.resume_from(&ckpt).expect("resume"), 2);
    let tail = second.run(&mut hub).expect("resumed run");
    for h in clients {
        h.join().expect("client");
    }
    let _ = std::fs::remove_file(&path);

    assert_eq!(head.rounds.len() + tail.rounds.len(), want.rounds.len());
    assert_eq!(
        format!("{:?}", &want.rounds[..2]),
        format!("{:?}", head.rounds),
        "pre-kill rounds diverged"
    );
    assert_eq!(
        format!("{:?}", &want.rounds[2..]),
        format!("{:?}", tail.rounds),
        "resumed rounds diverged from the uninterrupted run"
    );
    assert!(
        tail.rounds.iter().all(|r| r.stragglers == 0),
        "resume must not manufacture stragglers"
    );
    let theta: Vec<u32> = second.session().theta().iter().map(|x| x.to_bits()).collect();
    assert_eq!(theta_want, theta, "θ diverged bitwise across the kill/restart");
}

#[test]
fn prop_kill_and_resume_matches_uninterrupted_cf10() {
    kill_and_resume_matches(DatasetKind::Cf10);
}

#[test]
fn prop_kill_and_resume_matches_uninterrupted_cf100() {
    kill_and_resume_matches(DatasetKind::Cf100);
}

#[test]
fn prop_kill_and_resume_matches_uninterrupted_wt2() {
    kill_and_resume_matches(DatasetKind::Wt2);
}
