//! Property tests for the quantization stack (in-house seeded-case
//! harness; the offline registry has no proptest — see DESIGN.md S18).
//!
//! Each property runs hundreds of randomized cases over dimensions,
//! levels, and value scales.

use aquila::quant::levels::{aquila_level, aquila_level_upper_bound, aquila_tau_star};
use aquila::quant::midtread::{
    dequantize, quantize, quantize_innovation_fused, quantize_with_range, tau,
};
use aquila::quant::packing::{pack, packed_len, unpack};
use aquila::quant::qsgd;
use aquila::transport::wire::{decode, encode, wire_bits, Payload};
use aquila::util::rng::Xoshiro256pp;
use aquila::util::vecmath::{innovation_norms, norm2_sq};

fn random_vec(rng: &mut Xoshiro256pp, d: usize, scale: f32) -> Vec<f32> {
    (0..d).map(|_| rng.gaussian_f32(0.0, scale)).collect()
}

/// Per-element mid-tread error ≤ τR, for all (d, b, scale).
#[test]
fn prop_midtread_error_bound() {
    let mut rng = Xoshiro256pp::seed_from_u64(1000);
    for case in 0..300 {
        let d = 1 + rng.next_bounded(3000) as usize;
        let bits = 1 + rng.next_bounded(16) as u8;
        let scale = [1e-4f32, 1.0, 1e4][case % 3];
        let v = random_vec(&mut rng, d, scale);
        let q = quantize(&v, bits);
        let dq = dequantize(&q);
        // τR plus the f32 representation error of values near ±R (at
        // b = 16 and |v| ≈ 3e4 a single f32 ULP is ~2e-3 and the grid
        // step ~1, so the ULP term matters).
        let bound = tau(bits) * q.range as f64 * (1.0 + 1e-5)
            + q.range as f64 * f32::EPSILON as f64 * 4.0;
        for (i, (a, b)) in v.iter().zip(&dq).enumerate() {
            assert!(
                ((a - b).abs() as f64) <= bound + 1e-12,
                "case {case} d={d} b={bits} i={i}: |{a} - {b}| > {bound}"
            );
        }
    }
}

/// Codes always fit in `bits` bits.
#[test]
fn prop_codes_fit() {
    let mut rng = Xoshiro256pp::seed_from_u64(1001);
    for _ in 0..200 {
        let d = 1 + rng.next_bounded(500) as usize;
        let bits = 1 + rng.next_bounded(20) as u8;
        let v = random_vec(&mut rng, d, 2.0);
        let q = quantize(&v, bits);
        let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        assert!(q.psi.iter().all(|&c| c <= max));
    }
}

/// Packing round-trips exactly for every (codes, bits).
#[test]
fn prop_packing_roundtrip() {
    let mut rng = Xoshiro256pp::seed_from_u64(1002);
    for _ in 0..300 {
        let n = rng.next_bounded(1000) as usize;
        let bits = 1 + rng.next_bounded(32) as u8;
        let mask: u64 = if bits == 32 {
            u32::MAX as u64
        } else {
            (1u64 << bits) - 1
        };
        let codes: Vec<u32> = (0..n).map(|_| (rng.next_u64() & mask) as u32).collect();
        let packed = pack(&codes, bits);
        assert_eq!(packed.len(), packed_len(n, bits));
        assert_eq!(unpack(&packed, bits, n), codes);
    }
}

/// Wire encode/decode is the identity, and `wire_bits` = 8×bytes.
#[test]
fn prop_wire_roundtrip() {
    let mut rng = Xoshiro256pp::seed_from_u64(1003);
    for case in 0..200 {
        let d = 1 + rng.next_bounded(400) as usize;
        let v = random_vec(&mut rng, d, 1.0);
        let payload = match case % 5 {
            0 => Payload::MidtreadDelta(quantize(&v, 1 + (case % 13) as u8)),
            1 => Payload::MidtreadFull(quantize(&v, 1 + (case % 13) as u8)),
            2 => Payload::Qsgd(qsgd::quantize(&v, 1 + (case % 8) as u8, &mut rng)),
            3 => Payload::RawDelta(v.clone()),
            _ => Payload::RawFull(v.clone()),
        };
        let bytes = encode(&payload);
        assert_eq!(bytes.len() as u64 * 8, wire_bits(&payload));
        assert_eq!(decode(&bytes).unwrap(), payload);
    }
}

/// Theorem 1 self-consistency: 1 ≤ b* ≤ ceil(log2(√d + 1)) and
/// τ* ∈ (0, 1] — the "no clamping needed" property.
#[test]
fn prop_level_rule_self_consistent() {
    let mut rng = Xoshiro256pp::seed_from_u64(1004);
    for _ in 0..500 {
        let d = 1 + rng.next_bounded(5000) as usize;
        let v = random_vec(&mut rng, d, 3.0);
        let (l2sq, linf) = aquila::util::vecmath::l2sq_and_linf(&v);
        let b = aquila_level(l2sq.sqrt(), linf, v.len());
        assert!(b >= 1);
        assert!(b <= aquila_level_upper_bound(v.len()));
        let t = aquila_tau_star(l2sq.sqrt(), linf, v.len());
        assert!(t > 0.0 && t <= 1.0);
    }
}

/// The fused innovation path agrees with quantize + dequantize composed
/// and with materialized norms.
#[test]
fn prop_fused_equals_composed() {
    let mut rng = Xoshiro256pp::seed_from_u64(1005);
    for _ in 0..100 {
        let d = 1 + rng.next_bounded(2000) as usize;
        let bits = 1 + rng.next_bounded(12) as u8;
        let g = random_vec(&mut rng, d, 1.0);
        let q = random_vec(&mut rng, d, 1.0);
        let v: Vec<f32> = g.iter().zip(&q).map(|(a, b)| a - b).collect();
        let (_, linf) = innovation_norms(&g, &q);

        let mut dq = vec![0.0f32; d];
        let out = quantize_innovation_fused(&g, &q, bits, linf, &mut dq);
        let composed = quantize_with_range(&v, bits, linf);
        assert_eq!(out.quantized.psi, composed.psi);

        let dq_n = norm2_sq(&dq);
        assert!((out.dq_norm_sq - dq_n).abs() <= 1e-4 * dq_n.max(1.0));
        let err: Vec<f32> = v.iter().zip(&dq).map(|(a, b)| a - b).collect();
        let err_n = norm2_sq(&err);
        assert!((out.err_norm_sq - err_n).abs() <= 1e-4 * err_n.max(1e-12));
    }
}

/// Quantized-then-dequantized error norm shrinks monotonically (weakly)
/// as bits grow.
#[test]
fn prop_error_monotone_in_bits() {
    let mut rng = Xoshiro256pp::seed_from_u64(1006);
    for _ in 0..50 {
        let d = 16 + rng.next_bounded(1000) as usize;
        let v = random_vec(&mut rng, d, 1.0);
        let mut prev = f64::INFINITY;
        for bits in [1u8, 2, 4, 8, 12] {
            let q = quantize(&v, bits);
            let dq = dequantize(&q);
            let err: f64 = v
                .iter()
                .zip(&dq)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            assert!(
                err <= prev * (1.0 + 1e-9),
                "error grew from {prev} to {err} at b={bits}"
            );
            prev = err;
        }
    }
}

/// QSGD is unbiased across many draws (statistical property at coarse
/// tolerance).
#[test]
fn prop_qsgd_unbiased() {
    let mut rng = Xoshiro256pp::seed_from_u64(1007);
    let v = random_vec(&mut rng, 64, 1.0);
    let mut acc = vec![0.0f64; 64];
    let trials = 3000;
    for _ in 0..trials {
        let q = qsgd::quantize(&v, 3, &mut rng);
        for (a, x) in acc.iter_mut().zip(qsgd::dequantize(&q)) {
            *a += x as f64;
        }
    }
    let norm = norm2_sq(&v).sqrt();
    for (i, a) in acc.iter().enumerate() {
        let mean = a / trials as f64;
        assert!(
            (mean - v[i] as f64).abs() < 0.05 * norm,
            "coord {i}: {mean} vs {}",
            v[i]
        );
    }
}

/// Adversarial value patterns: subnormals, huge dynamic range, constant
/// vectors, alternating signs.
#[test]
fn prop_adversarial_patterns() {
    let patterns: Vec<Vec<f32>> = vec![
        vec![f32::MIN_POSITIVE; 64],
        (0..64)
            .map(|i| if i % 2 == 0 { 1e30 } else { 1e-30 })
            .collect(),
        vec![-1.0; 17],
        (0..33)
            .map(|i| if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect(),
        vec![0.0; 8],
    ];
    for (pi, v) in patterns.iter().enumerate() {
        for bits in [1u8, 4, 16] {
            let q = quantize(v, bits);
            let dq = dequantize(&q);
            let bound = tau(bits) * q.range as f64 * (1.0 + 1e-5) + 1e-30;
            for (a, b) in v.iter().zip(&dq) {
                assert!(
                    ((a - b).abs() as f64) <= bound,
                    "pattern {pi} bits {bits}: {a} -> {b}"
                );
            }
            // Wire round-trip stays exact even for extremes.
            let p = Payload::MidtreadFull(q);
            assert_eq!(decode(&encode(&p)).unwrap(), p);
        }
    }
}
