//! End-to-end behavioural tests: the paper's headline claims at reduced
//! scale (shape, not absolute numbers — see DESIGN.md §5).

use aquila::algorithms::{table_suite, Algorithm};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::repro::{ablation_beta, run_cell};
use std::sync::Arc;

fn tiny(ds: DatasetKind, split: SplitKind, hetero: bool) -> ExperimentSpec {
    let mut s = ExperimentSpec::new(ds, split, hetero).scaled(0.1, 60);
    s.devices = 8;
    s
}

/// Headline claim 1: at matched quality, AQUILA reaches the target
/// training loss with the fewest transmitted bits on a representative
/// row (CF-10 IID at reduced scale). Baselines that never reach the
/// target (e.g. a degenerately-skipping configuration) count as ∞ —
/// "cheap but never converges" is not a win.
#[test]
fn aquila_cheapest_to_target_on_cf10_iid() {
    use aquila::algorithms::fedavg::FedAvg;
    let spec = tiny(DatasetKind::Cf10, SplitKind::Iid, false);
    // Target: within 10% of what uncompressed FedAvg achieves.
    let t_fed = run_cell(&spec, Arc::new(FedAvg));
    let target = t_fed.final_train_loss() * 1.10;
    let mut costs = Vec::new();
    for algo in table_suite(spec.beta) {
        let t = run_cell(&spec, algo.clone());
        costs.push((algo.name().to_string(), t.bits_to_loss(target)));
    }
    let aq = costs
        .iter()
        .find(|r| r.0 == "AQUILA")
        .unwrap()
        .1
        .expect("AQUILA must reach the FedAvg-quality target");
    for (name, bits) in &costs {
        if name != "AQUILA" {
            match bits {
                None => {} // never reached target — infinitely expensive
                Some(b) => assert!(
                    aq < *b,
                    "AQUILA ({aq}) not cheaper to target than {name} ({b})"
                ),
            }
        }
    }
    // And at least four of the six baselines do reach the target (the
    // comparison is not vacuous).
    let reached = costs.iter().filter(|r| r.1.is_some()).count();
    assert!(reached >= 5, "only {reached} algorithms reached the target");
}

/// Headline claim 1 (LM row): cheapest-to-target on the WT-2 stand-in
/// versus every *every-round* baseline (QSGD, AdaQuantFL, MARINA, LENA).
/// The fixed-threshold lazy baselines (LAQ/LAdaQ) are excluded from the
/// strict comparison at this miniature scale: with the stand-in LM's
/// stagnant early loss they degenerate into near-total skipping and
/// free-ride on stale server gradients — a regime the paper's full-scale
/// experiments do not enter (EXPERIMENTS.md §Deviations discusses this).
#[test]
fn aquila_cheapest_to_target_on_wt2() {
    use aquila::algorithms::fedavg::FedAvg;
    let mut spec = tiny(DatasetKind::Wt2, SplitKind::Iid, false);
    spec.beta = 1.25;
    let t_fed = run_cell(&spec, Arc::new(FedAvg));
    let target = t_fed.final_train_loss() * 1.10;
    let mut aq_bits = None;
    let mut others = Vec::new();
    for algo in table_suite(spec.beta) {
        let t = run_cell(&spec, algo.clone());
        if algo.name() == "AQUILA" {
            aq_bits = t.bits_to_loss(target);
        } else if !matches!(algo.name(), "LAQ" | "LAdaQ") {
            others.push((algo.name().to_string(), t.bits_to_loss(target)));
        }
    }
    let aq = aq_bits.expect("AQUILA reaches target");
    for (name, bits) in others {
        if let Some(b) = bits {
            assert!(aq < b, "AQUILA {aq} ≥ {name} {b}");
        }
    }
}

/// Headline claim 2: AQUILA's per-round level stays within Theorem 1's
/// cap and fluctuates (no monotone growth) — unlike the AdaQuantFL rule
/// whose level is a monotone function of the decaying loss. (The
/// unbounded-growth pathology itself is exercised end-to-end on the
/// shared-center quadratic in `prop_coordinator`, where the loss
/// actually reaches ~0; these synthetic classification tasks have a
/// positive loss floor.)
#[test]
fn level_dynamics_match_paper() {
    use aquila::quant::levels::aquila_level_upper_bound;
    let spec = tiny(DatasetKind::Cf10, SplitKind::Iid, false);
    let suite = table_suite(spec.beta);
    let aq = suite.iter().find(|a| a.name() == "AQUILA").unwrap();
    let t_aq = run_cell(&spec, aq.clone());

    let d = spec.build_problem().dim();
    let cap = aquila_level_upper_bound(d) as f64;
    let mut seen = std::collections::BTreeSet::new();
    for r in &t_aq.rounds {
        assert!(r.mean_level <= cap + 1e-9);
        if r.mean_level > 0.0 {
            seen.insert((r.mean_level * 100.0) as u64);
        }
    }
    // "Fluctuates": more than one distinct level observed, and the
    // final level is NOT the maximum (no monotone ramp).
    assert!(seen.len() > 1, "level never changed");
    let last = t_aq
        .rounds
        .iter()
        .rev()
        .find(|r| r.mean_level > 0.0)
        .unwrap()
        .mean_level;
    let max = t_aq.rounds.iter().map(|r| r.mean_level).fold(0.0, f64::max);
    assert!(
        last < max + 1e-9 && seen.len() >= 2,
        "suspicious monotone level trace"
    );
}

/// Headline claim 3: comparable final quality — AQUILA's accuracy is
/// within a few points of uncompressed FedAvg on the Non-IID split.
#[test]
fn aquila_accuracy_comparable_noniid() {
    use aquila::algorithms::{aquila::Aquila, fedavg::FedAvg};
    let spec = {
        let mut s = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, false)
            .scaled(0.25, 150);
        s.devices = 10;
        s
    };
    let t_fed = run_cell(&spec, Arc::new(FedAvg));
    let t_aq = run_cell(&spec, Arc::new(Aquila::new(spec.beta)));
    let acc_fed = t_fed.final_accuracy().unwrap();
    let acc_aq = t_aq.final_accuracy().unwrap();
    assert!(
        acc_aq >= acc_fed - 0.08,
        "AQUILA acc {acc_aq} vs FedAvg {acc_fed}"
    );
    assert!(t_aq.total_bits() * 4 < t_fed.total_bits());
}

/// Headline claim 4 (Figures 4–5): increasing β trades convergence
/// speed for bits; moderate β keeps quality; huge β degrades it.
#[test]
fn beta_ablation_shape() {
    let mut spec = tiny(DatasetKind::Cf10, SplitKind::Iid, false);
    spec.rounds = 120;
    spec.data_scale = 0.2;
    let out = ablation_beta(&spec, &[0.0, 0.25, 1e6]);
    let (b0, mid, huge) = (&out[0].1, &out[1].1, &out[2].1);
    // Bits strictly decrease with β.
    assert!(b0.total_bits() > mid.total_bits());
    assert!(mid.total_bits() > huge.total_bits());
    // Moderate β ≈ no-skip quality.
    assert!(mid.final_train_loss() < b0.final_train_loss() * 1.5 + 0.1);
    // Absurd β: almost everything skipped after bootstrap ⇒ the model
    // barely trains.
    assert!(huge.final_train_loss() > mid.final_train_loss());
    let total = huge.total_uploads() + huge.total_skips();
    assert!(huge.total_skips() as f64 > 0.9 * total as f64);
}

/// Table III shape: heterogeneous runs cost less than homogeneous for
/// every algorithm, and AQUILA stays cheapest.
#[test]
fn hetero_table_shape() {
    let spec_h = tiny(DatasetKind::Cf10, SplitKind::Iid, false);
    let mut spec_het = spec_h.clone();
    spec_het.hetero = true;
    let mut aq_het = None;
    for algo in table_suite(spec_h.beta) {
        let homo = run_cell(&spec_h, algo.clone());
        let het = run_cell(&spec_het, algo.clone());
        assert!(
            het.total_bits() < homo.total_bits(),
            "{}: hetero {} ≥ homo {}",
            algo.name(),
            het.total_bits(),
            homo.total_bits()
        );
        if algo.name() == "AQUILA" {
            aq_het = Some(het.total_bits());
        }
    }
    assert!(aq_het.is_some());
}

/// The full 7-algorithm suite runs without panics on every dataset kind
/// (smoke over the whole matrix at minimal scale).
#[test]
fn full_matrix_smoke() {
    for ds in [DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2] {
        for split in [SplitKind::Iid, SplitKind::NonIid] {
            if ds == DatasetKind::Wt2 && split == SplitKind::NonIid {
                continue; // no such row in the paper
            }
            let mut spec = ExperimentSpec::new(ds, split, false).scaled(0.05, 8);
            spec.devices = 4;
            for algo in table_suite(spec.beta) {
                let t = run_cell(&spec, algo.clone());
                assert_eq!(t.rounds.len(), 8, "{} {:?}", algo.name(), ds);
                assert!(t.final_train_loss().is_finite());
            }
        }
    }
}
