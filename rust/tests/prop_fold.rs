//! Properties of the zero-copy shard-parallel server fold (ISSUE 2):
//!
//! * the fold is **bit-identical** to the serial fold for 1/2/7
//!   threads, across every payload kind and under HeteroFL masks;
//! * `unpack_range` agrees with `unpack` on random sub-ranges for every
//!   bit width 1..=32;
//! * the fused view fold matches the materializing
//!   decode → dequantize → scatter reference exactly.

use aquila::algorithms::ServerAgg;
use aquila::hetero::{half_half_masks, CapacityMask};
use aquila::problems::ParamLayout;
use aquila::quant::midtread::{dequantize_into as mt_dequantize_into, quantize};
use aquila::quant::packing::{pack, unpack, unpack_range};
use aquila::quant::qsgd;
use aquila::quant::{code_mask, max_code};
use aquila::transport::wire::{decode, upload_refs, EncodedUpload, Payload};
use aquila::util::rng::Xoshiro256pp;
use std::sync::Arc;

fn random_vec(rng: &mut Xoshiro256pp, d: usize, scale: f32) -> Vec<f32> {
    (0..d).map(|_| rng.gaussian_f32(0.0, scale)).collect()
}

/// One payload of each wire kind, sized for `support` elements.
fn payload_suite(rng: &mut Xoshiro256pp, support: usize) -> Vec<Payload> {
    let v = random_vec(rng, support, 1.5);
    vec![
        Payload::MidtreadDelta(quantize(&v, 4)),
        Payload::MidtreadFull(quantize(&v, 9)),
        Payload::Qsgd(qsgd::quantize(&v, 5, rng)),
        Payload::RawDelta(v.clone()),
        Payload::RawFull(v),
    ]
}

/// Materializing reference fold: decode each upload, dequantize into a
/// dense gathered vector, scatter-add through its mask — the exact
/// pre-PR pipeline, element-for-element.
fn reference_fold(
    dim: usize,
    masks: &[Arc<CapacityMask>],
    staged: &[EncodedUpload],
    scale: f32,
) -> Vec<f32> {
    let mut direction = vec![0.0f32; dim];
    for up in staged {
        let p = decode(&up.bytes).unwrap();
        let mask = &masks[up.device];
        let mut scratch = vec![0.0f32; p.len()];
        match &p {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
                mt_dequantize_into(q, &mut scratch)
            }
            Payload::Qsgd(q) => qsgd::dequantize_into(q, &mut scratch),
            Payload::RawDelta(v) | Payload::RawFull(v) => scratch.copy_from_slice(v),
        }
        mask.scatter_add(&scratch, scale, &mut direction);
    }
    direction
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

/// Shard-parallel fold ≡ serial fold, bitwise, for 1/2/7 threads, all
/// payload kinds, full masks.
///
/// Case 0 uses d = 60 000 — above 7 × the 8192-element shard floor —
/// so the 7-thread fold genuinely runs 7 shards (and the 2-thread fold
/// 2); the remaining cases keep small dimensions for the serial path.
#[test]
fn prop_fold_bit_identical_across_threads_full_masks() {
    let mut rng = Xoshiro256pp::seed_from_u64(9000);
    for case in 0..4 {
        let d = if case == 0 {
            60_000
        } else {
            100 + rng.next_bounded(4000) as usize
        };
        let m = 3 + rng.next_bounded(5) as usize;
        let full = Arc::new(CapacityMask::full(d));
        let masks: Vec<_> = (0..m).map(|_| full.clone()).collect();
        // Mixed payload kinds across devices.
        let kinds = payload_suite(&mut rng, d);
        let staged: Vec<EncodedUpload> = (0..m)
            .map(|dev| EncodedUpload::encode(dev, &kinds[dev % kinds.len()]))
            .collect();
        let scale = 1.0 / m as f32;

        let reference = reference_fold(d, &masks, &staged, scale);
        for threads in [1usize, 2, 7] {
            let mut srv = ServerAgg::new(d, masks.clone());
            srv.set_threads(threads);
            srv.accumulate(&upload_refs(&staged), scale);
            assert_bits_eq(
                &srv.direction,
                &reference,
                &format!("case {case} threads {threads}"),
            );
        }
    }
}

/// Same property under HeteroFL masks (100%–50% split): the masked
/// scatter through sorted indices is shard-partition-invariant too.
/// d = 33 000 crosses the 8192-element shard floor, so masked uploads
/// genuinely straddle shard boundaries on the multi-thread folds.
#[test]
fn prop_fold_bit_identical_under_hetero_masks() {
    let mut rng = Xoshiro256pp::seed_from_u64(9001);
    let layout = ParamLayout::contiguous(&[("w", vec![180, 150]), ("b", vec![6000])]);
    let d = layout.dim();
    assert!(d >= 4 * 8192, "test must span multiple fold shards");
    let m = 6;
    let masks = half_half_masks(&layout, m, 0.5);
    let staged: Vec<EncodedUpload> = (0..m)
        .map(|dev| {
            let support = masks[dev].support();
            let kinds = payload_suite(&mut rng, support);
            EncodedUpload::encode(dev, &kinds[dev % kinds.len()])
        })
        .collect();
    let scale = 1.0 / m as f32;

    let reference = reference_fold(d, &masks, &staged, scale);
    for threads in [1usize, 2, 7] {
        let mut srv = ServerAgg::new(d, masks.clone());
        srv.set_threads(threads);
        srv.accumulate(&upload_refs(&staged), scale);
        assert_bits_eq(&srv.direction, &reference, &format!("threads {threads}"));
    }
}

/// Folding twice accumulates (incremental semantics survive sharding;
/// d = 20 000 spans multiple 8192-element shards on the 7-thread fold).
#[test]
fn prop_fold_accumulates_across_rounds() {
    let mut rng = Xoshiro256pp::seed_from_u64(9002);
    let d = 20_000;
    let full = Arc::new(CapacityMask::full(d));
    let masks = vec![full; 3];
    let staged: Vec<EncodedUpload> = (0..3)
        .map(|dev| {
            let v = random_vec(&mut rng, d, 1.0);
            EncodedUpload::encode(dev, &Payload::MidtreadDelta(quantize(&v, 6)))
        })
        .collect();
    let once = {
        let mut srv = ServerAgg::new(d, masks.clone());
        srv.set_threads(2);
        srv.accumulate(&upload_refs(&staged), 0.5);
        srv.direction.clone()
    };
    let mut srv = ServerAgg::new(d, masks);
    srv.set_threads(7);
    srv.accumulate(&upload_refs(&staged), 0.5);
    srv.accumulate(&upload_refs(&staged), 0.5);
    let twice_serial: Vec<f32> = {
        // Reference: accumulate the single-fold result twice, in the
        // same per-element order.
        let mut acc = vec![0.0f32; d];
        for _ in 0..2 {
            let mut tmp = ServerAgg::new(d, vec![Arc::new(CapacityMask::full(d)); 3]);
            tmp.direction.copy_from_slice(&acc);
            tmp.accumulate(&upload_refs(&staged), 0.5);
            acc.copy_from_slice(&tmp.direction);
        }
        acc
    };
    assert_bits_eq(&srv.direction, &twice_serial, "two-round accumulate");
    // And one pass matches the one-pass reference.
    let mut one = ServerAgg::new(d, vec![Arc::new(CapacityMask::full(d)); 3]);
    one.accumulate(&upload_refs(&staged), 0.5);
    assert_bits_eq(&one.direction, &once, "one-round accumulate");
}

/// `unpack_range` agrees with `unpack` on random sub-ranges for every
/// bit width 1..=32 (the satellite coverage task).
#[test]
fn prop_unpack_range_agrees_with_unpack() {
    let mut rng = Xoshiro256pp::seed_from_u64(9003);
    for bits in 1..=32u8 {
        let n = 64 + rng.next_bounded(1500) as usize;
        let mask = code_mask(bits);
        let codes: Vec<u32> = (0..n).map(|_| (rng.next_u64() & mask) as u32).collect();
        let packed = pack(&codes, bits);
        let full = unpack(&packed, bits, n);
        assert_eq!(full, codes, "bits={bits} full unpack");
        for _ in 0..20 {
            let a = rng.next_bounded(n as u64 + 1) as usize;
            let b = rng.next_bounded(n as u64 + 1) as usize;
            let (start, end) = if a <= b { (a, b) } else { (b, a) };
            assert_eq!(
                unpack_range(&packed, bits, start, end),
                full[start..end],
                "bits={bits} range {start}..{end} of {n}"
            );
        }
    }
    // max_code sanity at the boundary widths.
    assert_eq!(max_code(1), 1);
    assert_eq!(max_code(32), u32::MAX);
}
