//! Coordinator invariants under randomized configurations (in-house
//! property harness): bit accounting, aggregation semantics, skip
//! behaviour, determinism, hetero masking, failure injection, and
//! selection-strategy properties — all through the `Session` API.

use aquila::algorithms::{
    adaquantfl::AdaQuantFl, aquila::Aquila, fedavg::FedAvg, laq::Laq, lena::Lena,
    marina::Marina, qsgd::QsgdAlgo, Algorithm,
};
use aquila::coordinator::{RunConfig, Session};
use aquila::hetero::{half_half_masks, CapacityMask};
use aquila::problems::quadratic::QuadraticProblem;
use aquila::problems::GradientSource;
use aquila::selection::SelectionSpec;
use aquila::transport::FaultSpec;
use aquila::util::rng::Xoshiro256pp;
use std::sync::Arc;

fn algorithms() -> Vec<Arc<dyn Algorithm>> {
    vec![
        Arc::new(FedAvg),
        Arc::new(QsgdAlgo::new(8)),
        Arc::new(AdaQuantFl::new(2, 32)),
        Arc::new(Laq::new(8, 0.8, 10)),
        Arc::new(Lena::new(0.8, 10)),
        Arc::new(Marina::new(8, 0.2)),
        Arc::new(Aquila::new(0.25)),
    ]
}

fn cfg(seed: u64, rounds: usize) -> RunConfig {
    RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds,
        eval_every: 0,
        seed,
        threads: 3,
        ..RunConfig::default()
    }
}

fn session(p: &Arc<QuadraticProblem>, algo: Arc<dyn Algorithm>, cfg: RunConfig) -> Session {
    Session::builder(p.clone(), algo).config(cfg).build()
}

/// Cumulative bits always equal the sum of per-round bits, bits are
/// strictly positive on upload rounds, and skip rounds bill zero.
#[test]
fn prop_bit_accounting_all_algorithms() {
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    for case in 0..6 {
        let d = 8 + rng.next_bounded(64) as usize;
        let m = 2 + rng.next_bounded(8) as usize;
        let p = Arc::new(QuadraticProblem::new(d, m, 0.5, 2.0, 0.5, case));
        for algo in algorithms() {
            let name = algo.name();
            let trace = session(&p, algo, cfg(case, 15)).run();
            let mut cum = 0u64;
            for r in &trace.rounds {
                cum += r.bits_up;
                assert_eq!(r.cum_bits, cum, "{name}");
                if r.uploads == 0 {
                    assert_eq!(r.bits_up, 0, "{name}: bits without uploads");
                }
                if r.bits_up == 0 {
                    assert_eq!(r.uploads, 0, "{name}: uploads without bits");
                }
                assert!(r.uploads + r.skips <= m);
            }
        }
    }
}

/// Round 0 bootstraps: every participating device uploads, regardless
/// of algorithm.
#[test]
fn prop_round_zero_all_upload() {
    let p = Arc::new(QuadraticProblem::new(32, 6, 0.5, 2.0, 0.5, 7));
    for algo in algorithms() {
        let name = algo.name();
        let mut s = session(&p, algo, cfg(1, 1));
        let rec = s.run_round(0);
        assert_eq!(rec.uploads, 6, "{name} bootstrap");
        assert_eq!(rec.skips, 0);
    }
}

/// Determinism: identical seeds ⇒ identical traces **and bit-identical
/// final models**, across thread counts (1/2/7 — exercising both the
/// parallel device phase and the shard-parallel server fold) and
/// algorithms.
#[test]
fn prop_determinism_across_threads() {
    let p = Arc::new(QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 9));
    for algo in algorithms() {
        let name = algo.name();
        let mut c1 = cfg(5, 12);
        c1.threads = 1;
        let mut s1 = session(&p, algo.clone(), c1);
        let t1 = s1.run();
        let theta1: Vec<u32> = s1.theta().iter().map(|x| x.to_bits()).collect();
        for threads in [2usize, 7] {
            let mut c = cfg(5, 12);
            c.threads = threads;
            let mut s = session(&p, algo.clone(), c);
            let t = s.run();
            assert_eq!(t1.total_bits(), t.total_bits(), "{name} t={threads}");
            for (a, b) in t1.rounds.iter().zip(&t.rounds) {
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{name} t={threads} round {}",
                    a.round
                );
                assert_eq!(a.uploads, b.uploads);
            }
            let theta: Vec<u32> = s.theta().iter().map(|x| x.to_bits()).collect();
            assert_eq!(theta1, theta, "{name} t={threads}: θ diverged bitwise");
        }
    }
}

/// Lazy-family equivalence: with β = 0 (never skip) AQUILA's trajectory
/// equals "everyone uploads innovations every round", and the server's
/// direction reconstructs the average stored quantized gradient —
/// eq. (5)'s bookkeeping.
#[test]
fn prop_aquila_beta0_uploads_everything() {
    let p = Arc::new(QuadraticProblem::new(16, 4, 0.5, 2.0, 0.5, 11));
    let mut c = cfg(3, 10);
    c.beta = 0.0;
    let trace = session(&p, Arc::new(Aquila::new(0.0)), c).run();
    assert_eq!(trace.total_skips(), 0);
    assert_eq!(trace.total_uploads(), 40);
}

/// Heterogeneous runs: no coordinate outside a device's mask is ever
/// touched by that device's uploads (checked indirectly: a run where
/// ALL devices share a 50% mask leaves the complementary coordinates of
/// θ exactly at their initial values).
#[test]
fn prop_hetero_mask_no_leak() {
    let p = Arc::new(QuadraticProblem::new(64, 4, 0.5, 2.0, 0.5, 13));
    let layout = p.layout();
    let half = Arc::new(CapacityMask::from_layout(&layout, 0.5));
    let masks = vec![half.clone(); 4];
    let mut coord = Session::builder(p.clone(), Arc::new(Aquila::new(0.1)))
        .config(cfg(15, 10))
        .masks(masks)
        .build();
    let theta0 = coord.theta().to_vec();
    for k in 0..10 {
        coord.run_round(k);
    }
    let theta = coord.theta();
    for i in 0..64u32 {
        let in_mask = half.indices.contains(&i);
        let moved = (theta[i as usize] - theta0[i as usize]).abs() > 0.0;
        if !in_mask {
            assert!(!moved, "coordinate {i} outside mask moved");
        }
    }
    // And the masked coordinates did move (training happened).
    assert!(half
        .indices
        .iter()
        .any(|&i| (theta[i as usize] - theta0[i as usize]).abs() > 1e-6));
}

/// The 100%–50% split reduces total bits for every always-upload
/// algorithm by roughly the support ratio.
#[test]
fn prop_hetero_bit_reduction_ratio() {
    let p = Arc::new(QuadraticProblem::new(256, 8, 0.5, 2.0, 0.5, 17));
    let t_full = session(&p, Arc::new(FedAvg), cfg(19, 5)).run();
    let masks = half_half_masks(&p.layout(), 8, 0.5);
    let support = masks[7].support();
    let t_het = Session::builder(p.clone(), Arc::new(FedAvg))
        .config(cfg(19, 5))
        .masks(masks)
        .build()
        .run();
    // Expected payload ratio: half devices full d, half at `support`.
    let expect = (0.5 + 0.5 * support as f64 / 256.0) * t_full.total_bits() as f64;
    let actual = t_het.total_bits() as f64;
    assert!(
        (actual - expect).abs() / expect < 0.05,
        "hetero bits {actual} vs expected {expect}"
    );
}

/// Fault injection: with drop probability p, delivered messages ≈
/// (1-p)·sent, bits are still billed for drops, and training still
/// converges for FedAvg.
#[test]
fn prop_fault_injection_accounting() {
    let p = Arc::new(QuadraticProblem::new(16, 8, 0.5, 2.0, 0.5, 21));
    let mut c = cfg(23, 60);
    c.alpha = 0.1;
    c.faults = FaultSpec {
        drop_prob: 0.3,
        seed: 5,
    };
    let trace = session(&p, Arc::new(FedAvg), c).run();
    // FedAvg sends every round; bits equal the no-fault case.
    let t2 = session(&p, Arc::new(FedAvg), cfg(23, 60)).run();
    assert_eq!(trace.total_bits(), t2.total_bits());
    let gap = trace.final_train_loss() - p.optimum_value();
    assert!(gap < 0.1, "no convergence under faults: gap {gap}");
}

/// MARINA sync cadence: with p_sync = 1 every round is raw (bits equal
/// FedAvg's); with p_sync = 0 only round 0 is raw.
#[test]
fn prop_marina_sync_extremes() {
    let p = Arc::new(QuadraticProblem::new(32, 4, 0.5, 2.0, 0.5, 25));
    let mut c_all = cfg(27, 8);
    c_all.marina_p_sync = 1.0;
    let t_all = session(&p, Arc::new(Marina::new(8, 1.0)), c_all).run();
    let t_fed = session(&p, Arc::new(FedAvg), cfg(27, 8)).run();
    assert_eq!(t_all.total_bits(), t_fed.total_bits());

    let mut c_none = cfg(29, 8);
    c_none.marina_p_sync = 0.0;
    let t_none = session(&p, Arc::new(Marina::new(8, 0.0)), c_none).run();
    assert!(t_none.total_bits() < t_fed.total_bits());
}

/// Loss estimates broadcast to AdaQuantFL drive its level up as
/// training converges (the Section-II pathology, observable end to
/// end).
#[test]
fn prop_adaquantfl_level_grows_e2e() {
    // Shared-center quadratic: f* = 0, so the loss ratio f(θ⁰)/f(θᵏ)
    // diverges as training converges — exposing the unbounded-level
    // pathology end to end.
    let p = Arc::new(QuadraticProblem::shared_center(32, 4, 0.5, 2.0, 31));
    let trace = session(&p, Arc::new(AdaQuantFl::new(2, 32)), cfg(33, 80)).run();
    let early = trace.rounds[1].mean_level;
    let late = trace.rounds.last().unwrap().mean_level;
    assert!(
        late > early * 2.0,
        "AdaQuantFL level did not grow: {early} -> {late}"
    );
    // And eventually hits the 32-bit cap the paper calls meaningless.
    assert!(late >= 30.0, "late level {late}");
}

/// AQUILA's level stays bounded by Theorem 1's cap throughout a run
/// while AdaQuantFL's exceeds it.
#[test]
fn prop_aquila_level_bounded_e2e() {
    use aquila::quant::levels::aquila_level_upper_bound;
    let p = Arc::new(QuadraticProblem::new(64, 4, 0.5, 2.0, 0.5, 37));
    let trace = session(&p, Arc::new(Aquila::new(0.25)), cfg(39, 60)).run();
    let cap = aquila_level_upper_bound(64) as f64;
    for r in &trace.rounds {
        assert!(
            r.mean_level <= cap + 1e-9,
            "round {}: level {} above cap {cap}",
            r.round,
            r.mean_level
        );
    }
}

// ---- selection-strategy properties -------------------------------------

fn strategy_specs() -> Vec<SelectionSpec> {
    vec![
        SelectionSpec::RandomK(3),
        SelectionSpec::RoundRobin(2),
        SelectionSpec::LossWeighted(3),
        SelectionSpec::Availability {
            period: 4,
            duty: 3,
            cap: Some(3),
        },
    ]
}

fn strategy_session(
    p: &Arc<QuadraticProblem>,
    algo: Arc<dyn Algorithm>,
    spec: SelectionSpec,
    seed: u64,
    rounds: usize,
) -> Session {
    Session::builder(p.clone(), algo)
        .config(cfg(seed, rounds))
        .selection_spec(spec)
        .build()
}

/// Per-round uploads never exceed the cohort the strategy selected
/// (`uploads ≤ |selected| ≤ cap`), across strategies and algorithms.
#[test]
fn prop_uploads_bounded_by_cohort_across_strategies() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 51));
    for spec in strategy_specs() {
        let cap = spec.cohort_cap().expect("all test specs are capped");
        for algo in [
            Arc::new(FedAvg) as Arc<dyn Algorithm>,
            Arc::new(QsgdAlgo::new(8)),
            Arc::new(Aquila::new(0.25)),
        ] {
            let name = algo.name();
            let trace = strategy_session(&p, algo, spec.clone(), 53, 16).run();
            for r in &trace.rounds {
                assert!(
                    r.uploads + r.skips <= cap,
                    "{name}/{spec}: round {} had {} participants > cap {cap}",
                    r.round,
                    r.uploads + r.skips
                );
            }
            assert!(trace.total_uploads() > 0, "{name}/{spec}: nothing uploaded");
        }
    }
}

/// Identical seeds ⇒ identical traces for every (stochastic or not)
/// selection strategy.
#[test]
fn prop_selection_deterministic_given_seed() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 55));
    for spec in strategy_specs() {
        let t1 = strategy_session(&p, Arc::new(Aquila::new(0.25)), spec.clone(), 57, 14).run();
        let t2 = strategy_session(&p, Arc::new(Aquila::new(0.25)), spec.clone(), 57, 14).run();
        assert_eq!(t1.total_bits(), t2.total_bits(), "{spec}");
        for (a, b) in t1.rounds.iter().zip(&t2.rounds) {
            assert_eq!(a.train_loss, b.train_loss, "{spec} round {}", a.round);
            assert_eq!(a.uploads, b.uploads, "{spec} round {}", a.round);
        }
    }
}

/// Round-robin visits every device: after `M` rounds at K = 1 each
/// device has participated exactly once; after `2M` rounds, twice.
#[test]
fn prop_round_robin_selects_everyone_eventually() {
    let m = 7;
    let p = Arc::new(QuadraticProblem::new(16, m, 0.5, 2.0, 0.5, 59));
    let mut s = strategy_session(
        &p,
        Arc::new(QsgdAlgo::new(8)),
        SelectionSpec::RoundRobin(1),
        61,
        2 * m,
    );
    for k in 0..2 * m {
        s.run_round(k);
    }
    for (dev, (uploads, skips)) in s.device_stats().into_iter().enumerate() {
        assert_eq!(
            uploads + skips,
            2,
            "device {dev} participated {} times",
            uploads + skips
        );
    }
}

/// Loss-weighted selection still covers unobserved devices (max-weight
/// exploration) and produces full-size cohorts.
#[test]
fn prop_loss_weighted_explores_and_fills_cohort() {
    let m = 6;
    let p = Arc::new(QuadraticProblem::new(16, m, 0.5, 2.0, 0.5, 63));
    let mut s = strategy_session(
        &p,
        Arc::new(FedAvg),
        SelectionSpec::LossWeighted(2),
        65,
        40,
    );
    let mut per_round_uploads = Vec::new();
    for k in 0..40 {
        per_round_uploads.push(s.run_round(k).uploads);
    }
    assert!(per_round_uploads.iter().all(|&u| u == 2));
    let touched = s
        .device_stats()
        .iter()
        .filter(|&&(u, sk)| u + sk > 0)
        .count();
    assert_eq!(touched, m, "only {touched}/{m} devices ever selected");
}

/// Checkpoint v3 resume equivalence under loss-weighted selection: a
/// run interrupted mid-way and restored from its snapshot selects the
/// same cohorts and reproduces the uninterrupted trace bit-for-bit
/// (loss history + per-device last losses persist; stochastic
/// strategies derive their RNG from `(seed, round)`).
#[test]
fn prop_loss_weighted_resume_equivalence() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 71));
    let algo = Arc::new(Aquila::new(0.25));
    let spec = SelectionSpec::LossWeighted(3);

    let mut uninterrupted = strategy_session(&p, algo.clone(), spec.clone(), 73, 16);
    let mut full_rounds = Vec::new();
    for k in 0..16 {
        full_rounds.push(uninterrupted.run_round(k));
    }

    // Interrupt at round 8: snapshot, rebuild a fresh session, restore.
    let mut first_half = strategy_session(&p, algo.clone(), spec.clone(), 73, 16);
    for k in 0..8 {
        first_half.run_round(k);
    }
    let ckpt = first_half.snapshot(8);
    let mut resumed = strategy_session(&p, algo, spec, 73, 16);
    let next = resumed.restore(&ckpt).unwrap();
    assert_eq!(next, 8);
    for k in 8..16 {
        let r = resumed.run_round(k);
        let f = &full_rounds[k];
        assert_eq!(
            r.train_loss.to_bits(),
            f.train_loss.to_bits(),
            "round {k} loss diverged after resume"
        );
        assert_eq!(r.uploads, f.uploads, "round {k} cohort diverged");
        assert_eq!(r.bits_up, f.bits_up, "round {k} bits diverged");
    }
    assert_eq!(resumed.theta(), uninterrupted.theta());
    assert_eq!(resumed.total_bits(), uninterrupted.total_bits());
}

/// Availability-aware selection: a device that is down this round is
/// never selected; with duty == period it degrades to (capped) full
/// participation.
#[test]
fn prop_availability_full_duty_is_full_participation() {
    let p = Arc::new(QuadraticProblem::new(16, 5, 0.5, 2.0, 0.5, 67));
    let spec = SelectionSpec::Availability {
        period: 3,
        duty: 3,
        cap: None,
    };
    let trace = strategy_session(&p, Arc::new(QsgdAlgo::new(8)), spec, 69, 6).run();
    assert!(trace.rounds.iter().all(|r| r.uploads == 5));
}
