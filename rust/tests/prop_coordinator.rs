//! Coordinator invariants under randomized configurations (in-house
//! property harness): bit accounting, aggregation semantics, skip
//! behaviour, determinism, hetero masking, and failure injection.

use aquila::algorithms::{
    adaquantfl::AdaQuantFl, aquila::Aquila, fedavg::FedAvg, laq::Laq, lena::Lena,
    marina::Marina, qsgd::QsgdAlgo, Algorithm,
};
use aquila::coordinator::{Coordinator, RunConfig};
use aquila::hetero::{half_half_masks, CapacityMask};
use aquila::problems::quadratic::QuadraticProblem;
use aquila::problems::GradientSource;
use aquila::transport::FaultSpec;
use aquila::util::rng::Xoshiro256pp;

fn algorithms() -> Vec<Box<dyn Algorithm>> {
    vec![
        Box::new(FedAvg),
        Box::new(QsgdAlgo::new(8)),
        Box::new(AdaQuantFl::new(2, 32)),
        Box::new(Laq::new(8, 0.8, 10)),
        Box::new(Lena::new(0.8, 10)),
        Box::new(Marina::new(8, 0.2)),
        Box::new(Aquila::new(0.25)),
    ]
}

fn cfg(seed: u64, rounds: usize) -> RunConfig {
    RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds,
        eval_every: 0,
        seed,
        threads: 3,
        ..RunConfig::default()
    }
}

/// Cumulative bits always equal the sum of per-round bits, bits are
/// strictly positive on upload rounds, and skip rounds bill zero.
#[test]
fn prop_bit_accounting_all_algorithms() {
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    for case in 0..6 {
        let d = 8 + rng.next_bounded(64) as usize;
        let m = 2 + rng.next_bounded(8) as usize;
        let p = QuadraticProblem::new(d, m, 0.5, 2.0, 0.5, case);
        for algo in algorithms() {
            let trace = Coordinator::new(&p, algo.as_ref(), cfg(case, 15)).run("q", "iid");
            let mut cum = 0u64;
            for r in &trace.rounds {
                cum += r.bits_up;
                assert_eq!(r.cum_bits, cum, "{}", algo.name());
                if r.uploads == 0 {
                    assert_eq!(r.bits_up, 0, "{}: bits without uploads", algo.name());
                }
                if r.bits_up == 0 {
                    assert_eq!(r.uploads, 0, "{}: uploads without bits", algo.name());
                }
                assert!(r.uploads + r.skips <= m);
            }
        }
    }
}

/// Round 0 bootstraps: every participating device uploads, regardless
/// of algorithm.
#[test]
fn prop_round_zero_all_upload() {
    let p = QuadraticProblem::new(32, 6, 0.5, 2.0, 0.5, 7);
    for algo in algorithms() {
        let mut c = Coordinator::new(&p, algo.as_ref(), cfg(1, 1));
        let rec = c.run_round(0);
        assert_eq!(rec.uploads, 6, "{} bootstrap", algo.name());
        assert_eq!(rec.skips, 0);
    }
}

/// Determinism: identical seeds ⇒ identical traces, across thread
/// counts and algorithms.
#[test]
fn prop_determinism_across_threads() {
    let p = QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 9);
    for algo in algorithms() {
        let mut c1 = cfg(5, 12);
        c1.threads = 1;
        let mut c4 = cfg(5, 12);
        c4.threads = 4;
        let t1 = Coordinator::new(&p, algo.as_ref(), c1).run("q", "iid");
        let t4 = Coordinator::new(&p, algo.as_ref(), c4).run("q", "iid");
        assert_eq!(t1.total_bits(), t4.total_bits(), "{}", algo.name());
        for (a, b) in t1.rounds.iter().zip(&t4.rounds) {
            assert_eq!(a.train_loss, b.train_loss, "{}", algo.name());
            assert_eq!(a.uploads, b.uploads);
        }
    }
}

/// Lazy-family equivalence: with β = 0 (never skip) AQUILA's trajectory
/// equals "everyone uploads innovations every round", and the server's
/// direction reconstructs the average stored quantized gradient —
/// eq. (5)'s bookkeeping.
#[test]
fn prop_aquila_beta0_uploads_everything() {
    let p = QuadraticProblem::new(16, 4, 0.5, 2.0, 0.5, 11);
    let algo = Aquila::new(0.0);
    let mut c = cfg(3, 10);
    c.beta = 0.0;
    let trace = Coordinator::new(&p, &algo, c).run("q", "iid");
    assert_eq!(trace.total_skips(), 0);
    assert_eq!(trace.total_uploads(), 40);
}

/// Heterogeneous runs: no coordinate outside a device's mask is ever
/// touched by that device's uploads (checked indirectly: a run where
/// ALL devices share a 50% mask leaves the complementary coordinates of
/// θ exactly at their initial values).
#[test]
fn prop_hetero_mask_no_leak() {
    let p = QuadraticProblem::new(64, 4, 0.5, 2.0, 0.5, 13);
    let layout = p.layout();
    let half = std::sync::Arc::new(CapacityMask::from_layout(&layout, 0.5));
    let masks = vec![half.clone(); 4];
    let algo = Aquila::new(0.1);
    let mut coord = Coordinator::with_masks(&p, &algo, masks, cfg(15, 10));
    let theta0 = coord.theta().to_vec();
    for k in 0..10 {
        coord.run_round(k);
    }
    let theta = coord.theta();
    for i in 0..64u32 {
        let in_mask = half.indices.contains(&i);
        let moved = (theta[i as usize] - theta0[i as usize]).abs() > 0.0;
        if !in_mask {
            assert!(!moved, "coordinate {i} outside mask moved");
        }
    }
    // And the masked coordinates did move (training happened).
    assert!(half
        .indices
        .iter()
        .any(|&i| (theta[i as usize] - theta0[i as usize]).abs() > 1e-6));
}

/// The 100%–50% split reduces total bits for every always-upload
/// algorithm by roughly the support ratio.
#[test]
fn prop_hetero_bit_reduction_ratio() {
    let p = QuadraticProblem::new(256, 8, 0.5, 2.0, 0.5, 17);
    let algo = FedAvg;
    let t_full = Coordinator::new(&p, &algo, cfg(19, 5)).run("q", "iid");
    let masks = half_half_masks(&p.layout(), 8, 0.5);
    let support = masks[7].support();
    let t_het = Coordinator::with_masks(&p, &algo, masks, cfg(19, 5)).run("q", "het");
    // Expected payload ratio: half devices full d, half at `support`.
    let expect = (0.5 + 0.5 * support as f64 / 256.0) * t_full.total_bits() as f64;
    let actual = t_het.total_bits() as f64;
    assert!(
        (actual - expect).abs() / expect < 0.05,
        "hetero bits {actual} vs expected {expect}"
    );
}

/// Fault injection: with drop probability p, delivered messages ≈
/// (1-p)·sent, bits are still billed for drops, and training still
/// converges for FedAvg.
#[test]
fn prop_fault_injection_accounting() {
    let p = QuadraticProblem::new(16, 8, 0.5, 2.0, 0.5, 21);
    let algo = FedAvg;
    let mut c = cfg(23, 60);
    c.alpha = 0.1;
    c.faults = FaultSpec {
        drop_prob: 0.3,
        seed: 5,
    };
    let trace = Coordinator::new(&p, &algo, c).run("q", "iid");
    // FedAvg sends every round; bits equal the no-fault case.
    let c2 = cfg(23, 60);
    let t2 = Coordinator::new(&p, &algo, c2).run("q", "iid");
    assert_eq!(trace.total_bits(), t2.total_bits());
    let gap = trace.final_train_loss() - p.optimum_value();
    assert!(gap < 0.1, "no convergence under faults: gap {gap}");
}

/// MARINA sync cadence: with p_sync = 1 every round is raw (bits equal
/// FedAvg's); with p_sync = 0 only round 0 is raw.
#[test]
fn prop_marina_sync_extremes() {
    let p = QuadraticProblem::new(32, 4, 0.5, 2.0, 0.5, 25);
    let mut c_all = cfg(27, 8);
    c_all.marina_p_sync = 1.0;
    let marina = Marina::new(8, 1.0);
    let t_all = Coordinator::new(&p, &marina, c_all).run("q", "iid");
    let fed = FedAvg;
    let t_fed = Coordinator::new(&p, &fed, cfg(27, 8)).run("q", "iid");
    assert_eq!(t_all.total_bits(), t_fed.total_bits());

    let mut c_none = cfg(29, 8);
    c_none.marina_p_sync = 0.0;
    let marina0 = Marina::new(8, 0.0);
    let t_none = Coordinator::new(&p, &marina0, c_none).run("q", "iid");
    assert!(t_none.total_bits() < t_fed.total_bits());
}

/// Loss estimates broadcast to AdaQuantFL drive its level up as
/// training converges (the Section-II pathology, observable end to
/// end).
#[test]
fn prop_adaquantfl_level_grows_e2e() {
    // Shared-center quadratic: f* = 0, so the loss ratio f(θ⁰)/f(θᵏ)
    // diverges as training converges — exposing the unbounded-level
    // pathology end to end.
    let p = QuadraticProblem::shared_center(32, 4, 0.5, 2.0, 31);
    let algo = AdaQuantFl::new(2, 32);
    let trace = Coordinator::new(&p, &algo, cfg(33, 80)).run("q", "iid");
    let early = trace.rounds[1].mean_level;
    let late = trace.rounds.last().unwrap().mean_level;
    assert!(
        late > early * 2.0,
        "AdaQuantFL level did not grow: {early} -> {late}"
    );
    // And eventually hits the 32-bit cap the paper calls meaningless.
    assert!(late >= 30.0, "late level {late}");
}

/// AQUILA's level stays bounded by Theorem 1's cap throughout a run
/// while AdaQuantFL's exceeds it.
#[test]
fn prop_aquila_level_bounded_e2e() {
    use aquila::quant::levels::aquila_level_upper_bound;
    let p = QuadraticProblem::new(64, 4, 0.5, 2.0, 0.5, 37);
    let algo = Aquila::new(0.25);
    let trace = Coordinator::new(&p, &algo, cfg(39, 60)).run("q", "iid");
    let cap = aquila_level_upper_bound(64) as f64;
    for r in &trace.rounds {
        assert!(
            r.mean_level <= cap + 1e-9,
            "round {}: level {} above cap {cap}",
            r.round,
            r.mean_level
        );
    }
}
