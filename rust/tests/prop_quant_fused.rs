//! Property tests for the fused quantize→pack device kernels (in-house
//! seeded-case harness; the offline registry has no proptest — see
//! DESIGN.md §18): the packed kernels must be *byte-identical* to
//! quantize-then-`pack_into` for every (bits, section spec, capacity
//! mask), bit-identical across thread counts, and immune to stale
//! bytes in recycled scratch buffers.

use aquila::hetero::CapacityMask;
use aquila::problems::ParamLayout;
use aquila::quant::midtread::{
    quantize_innovation_fused_sections_buf, quantize_innovation_packed_buf,
    quantize_innovation_packed_par, quantize_innovation_packed_sections_buf, quantize_sections,
    FUSED_BLOCK,
};
use aquila::quant::packing::pack;
use aquila::quant::qsgd;
use aquila::quant::SectionSpec;
use aquila::transport::wire::{encode, Payload};
use aquila::util::rng::Xoshiro256pp;

fn random_vec(rng: &mut Xoshiro256pp, d: usize, scale: f32) -> Vec<f32> {
    (0..d).map(|_| rng.gaussian_f32(0.0, scale)).collect()
}

/// A small multi-tensor layout whose dimension varies with the case.
fn random_layout(rng: &mut Xoshiro256pp) -> ParamLayout {
    let a = 8 + rng.next_bounded(64) as usize;
    let b = 4 + rng.next_bounded(32) as usize;
    let c = 1 + rng.next_bounded(96) as usize;
    ParamLayout::contiguous(&[
        ("w1", vec![a, b]),
        ("b1", vec![a]),
        ("w2", vec![c, a]),
        ("b2", vec![c]),
    ])
}

fn specs() -> [SectionSpec; 3] {
    [SectionSpec::Global, SectionSpec::Tensor, SectionSpec::Fixed(64)]
}

/// Per-section `‖g − q_prev‖_∞` — what `innovation_stats` feeds the
/// sectioned quantizers.
fn section_ranges(g: &[f32], q_prev: &[f32], sections: &aquila::quant::Sections) -> Vec<f32> {
    sections
        .iter()
        .map(|r| {
            g[r.clone()]
                .iter()
                .zip(&q_prev[r])
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
        })
        .collect()
}

/// Fused packed innovation kernel ≡ legacy fused quantize + `pack`,
/// byte for byte and bit for bit (norms, Δq, scales), over bits 1..=16
/// × section specs × random capacity masks.
#[test]
fn prop_innovation_packed_equals_quantize_then_pack() {
    let mut rng = Xoshiro256pp::seed_from_u64(7000);
    for case in 0..200 {
        let layout = random_layout(&mut rng);
        let ratio = [1.0f32, 0.75, 0.5, 0.3][case % 4];
        let mask = if ratio >= 1.0 {
            CapacityMask::full(layout.dim())
        } else {
            CapacityMask::from_layout(&layout, ratio)
        };
        let bits = 1 + (case % 16) as u8;
        for spec in specs() {
            let sections = spec.resolve(&layout, &mask);
            let n = sections.total();
            assert_eq!(n, mask.support());
            let g = random_vec(&mut rng, n, 1.0);
            let q_prev = random_vec(&mut rng, n, 0.5);
            let ranges = section_ranges(&g, &q_prev, &sections);
            let mut dq_ref = vec![0.0f32; n];
            let mut dq_packed = vec![0.0f32; n];
            let reference = quantize_innovation_fused_sections_buf(
                &g,
                &q_prev,
                bits,
                &ranges,
                &sections,
                &mut dq_ref,
                Vec::new(),
            );
            let packed = quantize_innovation_packed_sections_buf(
                &g,
                &q_prev,
                bits,
                &ranges,
                &sections,
                &mut dq_packed,
                Vec::new(),
            );
            let tag = format!("case {case} b={bits} {spec} ratio={ratio}");
            assert_eq!(
                packed.packed.body,
                pack(&reference.quantized.psi, bits),
                "{tag}: packed body != pack(psi)"
            );
            assert_eq!(packed.packed.bits, reference.quantized.bits, "{tag}");
            assert_eq!(
                packed.packed.scale.to_bits(),
                reference.quantized.range.to_bits(),
                "{tag}: scale"
            );
            assert_eq!(
                packed.packed.section_scales, reference.quantized.section_scales,
                "{tag}: section scales"
            );
            assert_eq!(packed.packed.dim(), n, "{tag}: dim");
            assert_eq!(
                packed.dq_norm_sq.to_bits(),
                reference.dq_norm_sq.to_bits(),
                "{tag}: dq norm"
            );
            assert_eq!(
                packed.err_norm_sq.to_bits(),
                reference.err_norm_sq.to_bits(),
                "{tag}: err norm"
            );
            for (i, (a, b)) in dq_ref.iter().zip(&dq_packed).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dq[{i}]");
            }
        }
    }
}

/// Full-gradient packed payloads (midtread and QSGD) encode to the
/// same wire bytes as their unpacked forms, across specs and masks —
/// the invariant that lets the engine swap payload forms without
/// perturbing any recorded trace.
#[test]
fn prop_packed_payload_wire_bytes_identical() {
    let mut rng = Xoshiro256pp::seed_from_u64(7001);
    for case in 0..150 {
        let layout = random_layout(&mut rng);
        let ratio = [1.0f32, 0.5, 0.3][case % 3];
        let mask = if ratio >= 1.0 {
            CapacityMask::full(layout.dim())
        } else {
            CapacityMask::from_layout(&layout, ratio)
        };
        let bits = 1 + (case % 12) as u8;
        for spec in specs() {
            let sections = spec.resolve(&layout, &mask);
            let v = random_vec(&mut rng, sections.total(), 2.0);
            let tag = format!("case {case} b={bits} {spec} ratio={ratio}");

            // Mid-tread full gradient.
            let unpacked = quantize_sections(&v, bits, &sections);
            let packed = aquila::quant::midtread::quantize_sections_packed_buf(
                &v,
                bits,
                &sections,
                Vec::new(),
            );
            assert_eq!(
                encode(&Payload::MidtreadFull(unpacked.clone())),
                encode(&Payload::MidtreadFullPacked(packed.clone())),
                "{tag}: midtread full wire bytes"
            );
            assert_eq!(
                encode(&Payload::MidtreadDelta(unpacked)),
                encode(&Payload::MidtreadDeltaPacked(packed)),
                "{tag}: midtread delta wire bytes"
            );

            // QSGD (stochastic: drive both paths from identically
            // seeded rng streams and require the streams to stay in
            // lockstep afterwards).
            let seed = 9000 + case as u64;
            let mut r1 = Xoshiro256pp::seed_from_u64(seed);
            let mut r2 = Xoshiro256pp::seed_from_u64(seed);
            let q_unpacked = qsgd::quantize_sections(&v, bits, &sections, &mut r1);
            let q_packed = qsgd::quantize_sections_packed_buf(&v, bits, &sections, &mut r2, Vec::new());
            assert_eq!(
                encode(&Payload::Qsgd(q_unpacked)),
                encode(&Payload::QsgdPacked(q_packed)),
                "{tag}: qsgd wire bytes"
            );
            assert_eq!(r1.next_u64(), r2.next_u64(), "{tag}: qsgd rng streams diverged");
        }
    }
}

/// The always-blocked parallel kernel is bitwise thread-invariant
/// (body bytes, Δq, norms across {1, 2, 7} threads), its bytes always
/// equal the serial kernel's, and at `d ≤ FUSED_BLOCK` its norms equal
/// the serial kernel's bitwise (single block ⇒ same accumulation
/// grouping).
#[test]
fn prop_packed_par_thread_invariant() {
    let mut rng = Xoshiro256pp::seed_from_u64(7002);
    let dims = [
        1usize,
        4097,
        FUSED_BLOCK - 1,
        FUSED_BLOCK,
        FUSED_BLOCK + 1,
        3 * FUSED_BLOCK + 1234,
    ];
    for (case, &d) in dims.iter().enumerate() {
        let bits = [1u8, 3, 4, 7, 12, 16][case % 6];
        let g = random_vec(&mut rng, d, 1.0);
        let q_prev = random_vec(&mut rng, d, 0.5);
        let range = g
            .iter()
            .zip(&q_prev)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let mut dq_serial = vec![0.0f32; d];
        let serial =
            quantize_innovation_packed_buf(&g, &q_prev, bits, range, &mut dq_serial, Vec::new());
        let mut first: Option<(Vec<u8>, u64, u64)> = None;
        for threads in [1usize, 2, 7] {
            let mut dq = vec![0.0f32; d];
            let out = quantize_innovation_packed_par(
                &g,
                &q_prev,
                bits,
                range,
                &mut dq,
                Vec::new(),
                threads,
            );
            let tag = format!("d={d} b={bits} t={threads}");
            // Bytes match the serial kernel at every thread count.
            assert_eq!(out.packed.body, serial.packed.body, "{tag}: body vs serial");
            assert_eq!(out.packed.scale.to_bits(), serial.packed.scale.to_bits(), "{tag}");
            // Δq is per-element and partition-independent.
            for (i, (a, b)) in dq_serial.iter().zip(&dq).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dq[{i}]");
            }
            // Norms are thread-invariant (fixed block grid).
            let sig = (
                out.packed.body.clone(),
                out.dq_norm_sq.to_bits(),
                out.err_norm_sq.to_bits(),
            );
            match &first {
                None => first = Some(sig),
                Some(f) => {
                    assert_eq!(f.1, sig.1, "{tag}: dq_norm_sq not thread-invariant");
                    assert_eq!(f.2, sig.2, "{tag}: err_norm_sq not thread-invariant");
                    assert_eq!(f.0, sig.0, "{tag}: body not thread-invariant");
                }
            }
            if d <= FUSED_BLOCK {
                assert_eq!(
                    out.dq_norm_sq.to_bits(),
                    serial.dq_norm_sq.to_bits(),
                    "{tag}: single-block norms must equal serial"
                );
                assert_eq!(
                    out.err_norm_sq.to_bits(),
                    serial.err_norm_sq.to_bits(),
                    "{tag}: single-block norms must equal serial"
                );
            }
        }
    }
}

/// Recycled scratch buffers never leak stale bytes: quantizing into a
/// poisoned, larger-capacity `body`/`dq` yields results identical to
/// fresh allocations, across shrinking sizes and repeated reuse.
#[test]
fn prop_scratch_reuse_no_stale_leakage() {
    let mut rng = Xoshiro256pp::seed_from_u64(7003);
    // Start with a large case so recycled buffers carry plenty of
    // stale capacity into the smaller ones.
    let mut body = vec![0xFFu8; 64 * 1024];
    body.clear();
    for case in 0..50 {
        let d = 1 + rng.next_bounded(2000) as usize;
        let bits = 1 + rng.next_bounded(16) as u8;
        let g = random_vec(&mut rng, d, 1.0);
        let q_prev = random_vec(&mut rng, d, 0.5);
        let range = g
            .iter()
            .zip(&q_prev)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // Poison the recycled buffer's spare capacity.
        let poison = body.capacity().min(4096);
        body.clear();
        body.resize(poison, 0xAB);
        let mut dq_fresh = vec![0.0f32; d];
        let mut dq_reused = vec![0.0f32; d];
        let fresh =
            quantize_innovation_packed_buf(&g, &q_prev, bits, range, &mut dq_fresh, Vec::new());
        let reused = quantize_innovation_packed_buf(
            &g,
            &q_prev,
            bits,
            range,
            &mut dq_reused,
            std::mem::take(&mut body),
        );
        let tag = format!("case {case} d={d} b={bits}");
        assert_eq!(fresh.packed.body, reused.packed.body, "{tag}: stale bytes leaked");
        assert_eq!(fresh.dq_norm_sq.to_bits(), reused.dq_norm_sq.to_bits(), "{tag}");
        assert_eq!(fresh.err_norm_sq.to_bits(), reused.err_norm_sq.to_bits(), "{tag}");
        for (a, b) in dq_fresh.iter().zip(&dq_reused) {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}");
        }
        body = reused.packed.body;
    }
}
