//! Network-scenario invariants through the `Session` API (in-house
//! property harness): bit-determinism across thread counts, trace
//! equivalence of infinite-deadline scenarios with the plain fault
//! path, round-keyed fault-RNG resume equivalence, straggler
//! semantics, and monotone simulated time.

use aquila::algorithms::{aquila::Aquila, fedavg::FedAvg, qsgd::QsgdAlgo, Algorithm};
use aquila::coordinator::{RunConfig, Session};
use aquila::problems::quadratic::QuadraticProblem;
use aquila::selection::SelectionSpec;
use aquila::transport::scenario::NetworkSpec;
use aquila::transport::FaultSpec;
use std::sync::Arc;

fn cfg(seed: u64, rounds: usize) -> RunConfig {
    RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds,
        eval_every: 0,
        seed,
        threads: 2,
        ..RunConfig::default()
    }
}

fn session(p: &Arc<QuadraticProblem>, algo: Arc<dyn Algorithm>, cfg: RunConfig) -> Session {
    Session::builder(p.clone(), algo).config(cfg).build()
}

/// Scenario simulation is bit-deterministic across engine thread
/// counts {1, 2, 7}: the transport phase is serial and all per-round
/// randomness is round-keyed, so the full trace — including `sim_time`
/// and straggler counts — and the final model agree bitwise.
#[test]
fn prop_scenario_deterministic_across_threads() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 41));
    let scenario = NetworkSpec::parse("cellular:deadline=0.08,jitter=0.2").unwrap();
    let make_cfg = |threads: usize| {
        let mut c = cfg(43, 14);
        c.threads = threads;
        c.network = scenario.clone();
        c.faults = FaultSpec {
            drop_prob: 0.2,
            seed: 5,
        };
        c
    };
    let mut s1 = session(&p, Arc::new(Aquila::new(0.25)), make_cfg(1));
    let t1 = s1.run();
    let theta1: Vec<u32> = s1.theta().iter().map(|x| x.to_bits()).collect();
    assert!(t1.total_stragglers() > 0, "scenario should straggle");
    for threads in [2usize, 7] {
        let mut s = session(&p, Arc::new(Aquila::new(0.25)), make_cfg(threads));
        let t = s.run();
        assert_eq!(t1.total_bits(), t.total_bits(), "t={threads}");
        for (a, b) in t1.rounds.iter().zip(&t.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "t={threads} round {}",
                a.round
            );
            assert_eq!(
                a.sim_time.to_bits(),
                b.sim_time.to_bits(),
                "t={threads} round {} sim_time",
                a.round
            );
            assert_eq!(a.stragglers, b.stragglers, "t={threads} round {}", a.round);
            assert_eq!(a.bits_down, b.bits_down, "t={threads} round {}", a.round);
        }
        let theta: Vec<u32> = s.theta().iter().map(|x| x.to_bits()).collect();
        assert_eq!(theta1, theta, "t={threads}: θ diverged bitwise");
    }
}

/// With `deadline = ∞` no upload is ever a straggler, so *any* link
/// population reproduces the plain `FaultSpec` path's learning trace
/// bit-exactly (same round-keyed fault stream, same delivered set) —
/// only the simulated clock differs, and it is monotone.
#[test]
fn prop_infinite_deadline_matches_fault_path() {
    let p = Arc::new(QuadraticProblem::new(24, 6, 0.5, 2.0, 0.5, 47));
    let faults = FaultSpec {
        drop_prob: 0.3,
        seed: 7,
    };
    let mut base_cfg = cfg(49, 16);
    base_cfg.faults = faults.clone();
    let baseline = session(&p, Arc::new(QsgdAlgo::new(6)), base_cfg).run();
    assert_eq!(baseline.total_sim_time(), 0.0, "ideal network takes no time");
    for net in ["lan", "wan", "cellular", "edge-mix:jitter=0.3"] {
        let mut c = cfg(49, 16);
        c.faults = faults.clone();
        c.network = NetworkSpec::parse(net).unwrap();
        let t = session(&p, Arc::new(QsgdAlgo::new(6)), c).run();
        assert_eq!(t.total_stragglers(), 0, "{net}: ∞ deadline cannot straggle");
        assert!(t.total_sim_time() > 0.0, "{net}: slow links take time");
        let mut prev = 0.0;
        for (a, b) in baseline.rounds.iter().zip(&t.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{net} round {}",
                a.round
            );
            assert_eq!(a.bits_up, b.bits_up, "{net} round {}", a.round);
            assert_eq!(a.uploads, b.uploads, "{net} round {}", a.round);
            assert!(b.sim_time >= prev, "{net} round {}: sim_time not monotone", a.round);
            prev = b.sim_time;
        }
    }
}

/// The fault RNG is round-keyed: a run interrupted mid-way under
/// nonzero `drop_prob` and restored from its checkpoint replays
/// exactly the drops the uninterrupted run saw (the free-running
/// stream this PR replaced diverged here — the same bug PR 2 fixed
/// for stochastic selection).
#[test]
fn prop_fault_rng_resume_equivalence() {
    let p = Arc::new(QuadraticProblem::new(24, 8, 0.5, 2.0, 0.5, 53));
    let algo: Arc<dyn Algorithm> = Arc::new(QsgdAlgo::new(6));
    let make_cfg = || {
        let mut c = cfg(55, 16);
        c.faults = FaultSpec {
            drop_prob: 0.3,
            seed: 11,
        };
        c.network = NetworkSpec::parse("cellular:deadline=0.15,jitter=0.1").unwrap();
        c
    };

    let mut uninterrupted = session(&p, algo.clone(), make_cfg());
    let mut full_rounds = Vec::new();
    for k in 0..16 {
        full_rounds.push(uninterrupted.run_round(k));
    }

    let mut first_half = session(&p, algo.clone(), make_cfg());
    for k in 0..8 {
        first_half.run_round(k);
    }
    let ckpt = first_half.snapshot(8);
    let mut resumed = session(&p, algo, make_cfg());
    let next = resumed.restore(&ckpt).unwrap();
    assert_eq!(next, 8);
    for k in 8..16 {
        let r = resumed.run_round(k);
        let f = &full_rounds[k];
        assert_eq!(
            r.train_loss.to_bits(),
            f.train_loss.to_bits(),
            "round {k}: drops diverged after resume"
        );
        assert_eq!(r.bits_up, f.bits_up, "round {k}");
        assert_eq!(r.uploads, f.uploads, "round {k}");
        assert_eq!(r.stragglers, f.stragglers, "round {k}");
        // v4 checkpoints carry the cumulative clock, so resumed
        // time-to-accuracy curves line up exactly.
        assert_eq!(r.sim_time.to_bits(), f.sim_time.to_bits(), "round {k}");
    }
    assert_eq!(resumed.theta(), uninterrupted.theta());
    assert_eq!(resumed.total_bits(), uninterrupted.total_bits());
    assert_eq!(resumed.total_bits_down(), uninterrupted.total_bits_down());
}

/// The acceptance scenario: a cellular fleet with a tight deadline
/// under availability-aware selection produces nonzero straggler
/// counts, a strictly monotone simulated clock, and still-finite
/// training losses; `time_to_loss` is consistent with the per-round
/// records.
#[test]
fn prop_cellular_deadline_produces_stragglers() {
    let p = Arc::new(QuadraticProblem::new(24, 10, 0.5, 2.0, 0.5, 59));
    let mut c = cfg(61, 30);
    c.alpha = 0.1;
    c.network = NetworkSpec::parse("cellular:deadline=0.08").unwrap();
    let trace = Session::builder(p.clone(), Arc::new(FedAvg))
        .config(c)
        .selection_spec(SelectionSpec::Availability {
            period: 4,
            duty: 3,
            cap: None,
        })
        .build()
        .run();
    assert!(trace.total_stragglers() > 0, "tight deadline must straggle");
    let mut prev = 0.0;
    for r in &trace.rounds {
        assert!(r.sim_time >= prev, "round {}: sim_time not monotone", r.round);
        assert!(r.round_time >= 0.0);
        assert!(r.train_loss.is_finite(), "round {}", r.round);
        assert!(r.stragglers <= r.uploads, "stragglers among staged uploads only");
        prev = r.sim_time;
    }
    assert!(trace.total_sim_time() > 0.0);
    // time_to_loss agrees with the cumulative clock of the first round
    // reaching the target.
    let target = trace.rounds[trace.rounds.len() / 2].train_loss;
    let t = trace.time_to_loss(target).expect("target was reached");
    let hit = trace
        .rounds
        .iter()
        .find(|r| r.train_loss <= target)
        .unwrap();
    assert_eq!(t, hit.sim_time);
}

/// `policy=late` only stretches the clock: the delivered uploads — and
/// therefore the whole learning trace — are bit-identical to the same
/// scenario without a deadline; stragglers are counted but kept.
#[test]
fn prop_admit_late_preserves_learning_trace() {
    let p = Arc::new(QuadraticProblem::new(24, 6, 0.5, 2.0, 0.5, 67));
    let mut c_inf = cfg(69, 14);
    c_inf.network = NetworkSpec::parse("cellular").unwrap();
    let t_inf = session(&p, Arc::new(FedAvg), c_inf).run();

    let mut c_late = cfg(69, 14);
    c_late.network = NetworkSpec::parse("cellular:deadline=0.08,policy=late").unwrap();
    let t_late = session(&p, Arc::new(FedAvg), c_late).run();

    assert!(t_late.total_stragglers() > 0, "late uploads are still counted");
    assert_eq!(t_inf.total_bits(), t_late.total_bits());
    for (a, b) in t_inf.rounds.iter().zip(&t_late.rounds) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "round {}: admit-late must not change learning",
            a.round
        );
    }
}

/// Deadline accounting: under `policy=drop` with a deadline generous
/// enough that no upload ever misses it, the round closes at the
/// slowest *arrival*, not at the deadline — clock and learning trace
/// are bit-identical to the same scenario with no deadline at all.
/// (The seed billed the configured deadline whenever one was set,
/// stretching `sim_time` by orders of magnitude on generous
/// deadlines.)
#[test]
fn prop_generous_deadline_bills_arrival_time() {
    let p = Arc::new(QuadraticProblem::new(24, 6, 0.5, 2.0, 0.5, 79));
    let faults = FaultSpec {
        drop_prob: 0.25,
        seed: 13,
    };
    let mut c_inf = cfg(81, 14);
    c_inf.faults = faults.clone();
    c_inf.network = NetworkSpec::parse("cellular:jitter=0.2").unwrap();
    let t_inf = session(&p, Arc::new(QsgdAlgo::new(6)), c_inf).run();

    let mut c_huge = cfg(81, 14);
    c_huge.faults = faults;
    c_huge.network = NetworkSpec::parse("cellular:deadline=1000000,jitter=0.2").unwrap();
    let t_huge = session(&p, Arc::new(QsgdAlgo::new(6)), c_huge).run();

    assert_eq!(t_huge.total_stragglers(), 0, "nobody misses a 10⁶ s deadline");
    for (a, b) in t_inf.rounds.iter().zip(&t_huge.rounds) {
        assert_eq!(
            a.round_time.to_bits(),
            b.round_time.to_bits(),
            "round {}: a generous deadline must bill max(arrival), not the deadline",
            a.round
        );
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {}", a.round);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "round {}",
            a.round
        );
    }
}

/// A transport-side availability trace (`avail=P/D`) bills every
/// staged upload but loses the down devices' messages; training still
/// converges on what arrives.
#[test]
fn prop_network_availability_converges() {
    let p = Arc::new(QuadraticProblem::new(16, 8, 0.5, 2.0, 0.5, 71));
    let mut c = cfg(73, 80);
    c.alpha = 0.1;
    c.network = NetworkSpec::parse("ideal:avail=4/3").unwrap();
    let trace = session(&p, Arc::new(FedAvg), c).run();
    // Bits are billed for every staged upload, reachable or not.
    let mut c_ref = cfg(73, 80);
    c_ref.alpha = 0.1;
    let t_ref = session(&p, Arc::new(FedAvg), c_ref).run();
    assert_eq!(trace.total_bits(), t_ref.total_bits());
    let gap = trace.final_train_loss() - p.optimum_value();
    assert!(gap < 0.1, "no convergence under availability trace: gap {gap}");
}
