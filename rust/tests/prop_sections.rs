//! Properties of layout-aware sectioned quantization (ISSUE 5):
//!
//! * **global-mode invariance** — the default `quant_sections =
//!   "global"` run is bit-identical to any configuration that resolves
//!   to a single section (`fixed:huge`, `tensor` over a single-tensor
//!   layout), on all three synth datasets: the sectioned machinery is
//!   provably dormant by default;
//! * **per-section error dominance** — with per-section scales, each
//!   section's quantization error is no worse than under the single
//!   global scale (equal for the range-dominant section, strictly
//!   smaller for the others when scales are heterogeneous);
//! * **fold determinism** — the shard-parallel fold over sectioned
//!   payloads is bit-identical across thread counts {1, 2, 7} and
//!   under HeteroFL capacity masks.

use aquila::algorithms::aquila::Aquila;
use aquila::algorithms::qsgd::QsgdAlgo;
use aquila::algorithms::{Algorithm, ServerAgg};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::hetero::{half_half_masks, CapacityMask};
use aquila::metrics::RunTrace;
use aquila::problems::ParamLayout;
use aquila::quant::midtread::{
    dequantize_into as mt_dequantize_into, quantize, quantize_sections,
};
use aquila::quant::qsgd;
use aquila::quant::{SectionSpec, Sections};
use aquila::repro::session_for;
use aquila::transport::wire::{decode, upload_refs, EncodedUpload, Payload};
use aquila::util::rng::Xoshiro256pp;
use std::sync::Arc;

fn assert_traces_bit_equal(a: &RunTrace, b: &RunTrace, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.bits_up, y.bits_up, "{what} round {}", x.round);
        assert_eq!(x.cum_bits, y.cum_bits, "{what} round {}", x.round);
        assert_eq!(x.uploads, y.uploads, "{what} round {}", x.round);
        assert_eq!(x.skips, y.skips, "{what} round {}", x.round);
        assert_eq!(
            x.mean_level.to_bits(),
            y.mean_level.to_bits(),
            "{what} round {}",
            x.round
        );
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{what} round {}",
            x.round
        );
        assert_eq!(
            x.eval_loss.map(f64::to_bits),
            y.eval_loss.map(f64::to_bits),
            "{what} round {}",
            x.round
        );
        assert_eq!(
            x.accuracy.map(f64::to_bits),
            y.accuracy.map(f64::to_bits),
            "{what} round {}",
            x.round
        );
    }
}

/// Global mode is the default and resolves identically to any
/// single-section configuration: traces must match bit-for-bit on all
/// three datasets (the "global is byte-identical to pre-sectioning"
/// pin — the single-section code path *is* the pre-PR path).
#[test]
fn prop_global_mode_traces_bit_equal_on_all_datasets() {
    for ds in [DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2] {
        let mut spec = ExperimentSpec::new(ds, SplitKind::Iid, false).scaled(0.05, 8);
        spec.devices = 6;
        assert_eq!(spec.quant_sections, SectionSpec::Global);
        let t_default = session_for(&spec, Arc::new(Aquila::new(spec.beta)))
            .build()
            .run();
        // fixed:N with N ≥ d resolves to one section — must be the
        // exact same run, wire bytes included.
        let mut spec_one = spec.clone();
        spec_one.quant_sections = SectionSpec::Fixed(1 << 30);
        let t_one = session_for(&spec_one, Arc::new(Aquila::new(spec.beta)))
            .build()
            .run();
        assert_traces_bit_equal(&t_default, &t_one, ds.name());
    }
    // `tensor` over a single-tensor layout (WT-2's bigram LM) likewise
    // degenerates to the global run.
    let mut spec = ExperimentSpec::new(DatasetKind::Wt2, SplitKind::Iid, false).scaled(0.05, 6);
    spec.devices = 4;
    let t_global = session_for(&spec, Arc::new(Aquila::new(spec.beta)))
        .build()
        .run();
    let mut spec_t = spec.clone();
    spec_t.quant_sections = SectionSpec::Tensor;
    let t_tensor = session_for(&spec_t, Arc::new(Aquila::new(spec.beta)))
        .build()
        .run();
    assert_traces_bit_equal(&t_global, &t_tensor, "wt2 tensor≡global");
}

/// Per-section quantization error under per-section scales is no worse
/// than under the global scale, section by section — equal on the
/// section owning the global range, strictly smaller on sections whose
/// own range is far below it.
#[test]
fn prop_per_section_error_dominates_global() {
    let mut rng = Xoshiro256pp::seed_from_u64(8200);
    for case in 0..20 {
        // 3–6 sections with scales spread over ~3 orders of magnitude.
        let n_sections = 3 + (case % 4);
        let lens: Vec<usize> = (0..n_sections)
            .map(|_| 50 + rng.next_bounded(200) as usize)
            .collect();
        let sections = Sections::from_lens(lens.iter().copied());
        let mut v = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            let scale = 10f32.powi(i as i32 % 4) * 0.05;
            v.extend((0..len).map(|_| rng.gaussian_f32(0.0, scale)));
        }
        for bits in [2u8, 4, 8] {
            let q_global = quantize(&v, bits);
            let q_sect = quantize_sections(&v, bits, &sections);
            let mut dq_global = vec![0.0f32; v.len()];
            mt_dequantize_into(&q_global, &mut dq_global);
            let mut dq_sect = vec![0.0f32; v.len()];
            mt_dequantize_into(&q_sect, &mut dq_sect);
            let mut total_g = 0.0f64;
            let mut total_s = 0.0f64;
            for (s, r) in sections.iter().enumerate() {
                let err = |dq: &[f32]| -> f64 {
                    v[r.clone()]
                        .iter()
                        .zip(&dq[r.clone()])
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum()
                };
                let e_g = err(&dq_global);
                let e_s = err(&dq_sect);
                total_g += e_g;
                total_s += e_s;
                assert!(
                    e_s <= e_g * (1.0 + 1e-9) + 1e-12,
                    "case {case} bits={bits} section {s}: sectioned {e_s} > global {e_g}"
                );
            }
            // And strictly better in aggregate for heterogeneous scales.
            assert!(
                total_s < total_g,
                "case {case} bits={bits}: no aggregate improvement ({total_s} vs {total_g})"
            );
        }
    }
}

/// Materializing reference fold for sectioned payloads: decode each
/// upload, dequantize (section-aware) into a dense gathered vector,
/// scatter-add through its mask.
fn reference_fold(
    dim: usize,
    masks: &[Arc<CapacityMask>],
    staged: &[EncodedUpload],
    scale: f32,
) -> Vec<f32> {
    let mut direction = vec![0.0f32; dim];
    for up in staged {
        let p = decode(&up.bytes).unwrap();
        let mask = &masks[up.device];
        let mut scratch = vec![0.0f32; p.len()];
        match &p {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
                mt_dequantize_into(q, &mut scratch)
            }
            Payload::Qsgd(q) => qsgd::dequantize_into(q, &mut scratch),
            Payload::RawDelta(v) | Payload::RawFull(v) => scratch.copy_from_slice(v),
        }
        mask.scatter_add(&scratch, scale, &mut direction);
    }
    direction
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

/// Shard-parallel fold over sectioned payloads ≡ serial fold, bitwise,
/// for 1/2/7 threads, across tensor and fixed sectioning, full and
/// HeteroFL half-capacity masks. d = 60 000 keeps the 7-thread fold
/// genuinely multi-shard (shard floor is 8192).
#[test]
fn prop_sectioned_fold_bit_identical_across_threads_and_masks() {
    let mut rng = Xoshiro256pp::seed_from_u64(8300);
    // An MLP-shaped layout summing to 60 000 parameters.
    let layout = ParamLayout::contiguous(&[
        ("w1", vec![100, 500]),
        ("b1", vec![100]),
        ("w2", vec![19, 500]),
        ("b2", vec![400]),
    ]);
    let d = layout.dim();
    assert_eq!(d, 60_000);
    let m = 6;
    let masks = half_half_masks(&layout, m, 0.5);
    for spec in [SectionSpec::Tensor, SectionSpec::Fixed(777)] {
        let staged: Vec<EncodedUpload> = (0..m)
            .map(|dev| {
                let sections = spec.resolve(&layout, &masks[dev]);
                let v: Vec<f32> = (0..masks[dev].support())
                    .map(|_| rng.gaussian_f32(0.0, 1.5))
                    .collect();
                let p = match dev % 3 {
                    0 => Payload::MidtreadDelta(quantize_sections(&v, 4, &sections)),
                    1 => Payload::MidtreadFull(quantize_sections(&v, 9, &sections)),
                    _ => Payload::Qsgd(qsgd::quantize_sections(&v, 5, &sections, &mut rng)),
                };
                EncodedUpload::encode(dev, &p)
            })
            .collect();
        let scale = 1.0 / m as f32;
        let reference = reference_fold(d, &masks, &staged, scale);
        for threads in [1usize, 2, 7] {
            let mut srv = ServerAgg::new(d, masks.clone());
            srv.set_threads(threads);
            srv.accumulate(&upload_refs(&staged), scale);
            assert_bits_eq(
                &srv.direction,
                &reference,
                &format!("{spec} threads={threads}"),
            );
        }
    }
}

/// End-to-end sectioned runs under HeteroFL masks stay bit-identical
/// across engine thread counts, for both the deterministic mid-tread
/// family (AQUILA) and the stochastic QSGD baseline.
#[test]
fn prop_sectioned_runs_thread_invariant_under_hetero_masks() {
    let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, true).scaled(0.05, 6);
    spec.devices = 6;
    spec.quant_sections = SectionSpec::Tensor;
    let algos: Vec<Arc<dyn Algorithm>> =
        vec![Arc::new(Aquila::new(spec.beta)), Arc::new(QsgdAlgo::new(5))];
    for algo in algos {
        let mut traces = Vec::new();
        for threads in [1usize, 2, 7] {
            let mut builder = session_for(&spec, algo.clone());
            let mut cfg = spec.run_config();
            cfg.threads = threads;
            builder = builder.config(cfg);
            traces.push(builder.build().run());
        }
        assert_traces_bit_equal(&traces[0], &traces[1], algo.name());
        assert_traces_bit_equal(&traces[0], &traces[2], algo.name());
    }
}
