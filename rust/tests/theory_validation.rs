//! Section-IV theory validated against measured runs on the quadratic
//! problem, where `L`, `μ`, `θ*` and `f*` are exact.

use aquila::algorithms::aquila::Aquila;
use aquila::coordinator::{RunConfig, Session};
use aquila::problems::quadratic::QuadraticProblem;
use aquila::problems::GradientSource;
use aquila::theory;
use std::sync::Arc;

fn run_cfg(alpha: f32, beta: f32, rounds: usize) -> RunConfig {
    RunConfig {
        alpha,
        beta,
        rounds,
        eval_every: 0,
        seed: 7,
        threads: 2,
        ..RunConfig::default()
    }
}

/// Theorem 3: with hyperparameters satisfying the feasibility
/// condition, AQUILA's measured loss gap contracts at least geometric-
/// ally and reaches ε within the predicted K (up to constant slack).
#[test]
fn theorem3_round_count_brackets_measured() {
    let p = Arc::new(QuadraticProblem::new(48, 8, 0.5, 2.0, 0.5, 101));
    let l = p.smoothness();
    let mu = p.pl_constant();
    let alpha = (0.5 / l) as f32;
    // Feasible β for a conservative γ estimate.
    let gamma = 2.0;
    let beta = (theory::max_feasible_beta(l, alpha as f64, gamma) * 0.5) as f32;
    assert!(theory::corollary1_condition(l, alpha as f64, beta as f64, gamma));

    let mut coord = Session::builder(p.clone(), Arc::new(Aquila::new(beta)))
        .config(run_cfg(alpha, beta, 400))
        .build();
    let fstar = p.optimum_value();
    let mut gaps = Vec::new();
    for k in 0..400 {
        let rec = coord.run_round(k);
        gaps.push(rec.train_loss - fstar);
    }
    let eps = 1e-4;
    let omega1 = gaps[0].max(1e-12);
    let k_pred = theory::theorem3_rounds(
        omega1 + fstar,
        fstar,
        0.0,
        alpha as f64,
        l,
        mu,
        eps,
    );
    // Measured first round where the gap ≤ ε.
    let k_meas = gaps.iter().position(|&g| g <= eps);
    let k_meas = k_meas.expect("never reached epsilon — convergence broken") as f64;
    // The bound must hold (measured ≤ predicted); it shouldn't be
    // vacuously loose either (within ~50× for this well-conditioned
    // problem).
    assert!(
        k_meas <= k_pred.ceil() + 1.0,
        "measured {k_meas} rounds > Theorem-3 bound {k_pred}"
    );
    assert!(
        k_pred <= 50.0 * k_meas.max(1.0),
        "bound uselessly loose: {k_pred} vs measured {k_meas}"
    );
}

/// Theorem 3's contraction, measured on its own Lyapunov quantity
/// `Vᵏ = f(θᵏ) − f* + (1/(2α) − L/2)‖θᵏ − θ^{k−1}‖²` (eq. 45): the
/// geometric-mean per-round factor over the run is ≤ (1 − αμ) up to a
/// small slack (individual skip-heavy rounds may contract less; the
/// theorem's telescoped product is what matters).
#[test]
fn measured_contraction_beats_theorem3_rate() {
    let p = Arc::new(QuadraticProblem::new(32, 6, 0.5, 2.0, 0.3, 103));
    let l = p.smoothness();
    let mu = p.pl_constant();
    let alpha = (0.5 / l) as f32;
    let beta = (theory::max_feasible_beta(l, alpha as f64, 2.0) * 0.5) as f32;
    let mut coord = Session::builder(p.clone(), Arc::new(Aquila::new(beta)))
        .config(run_cfg(alpha, beta, 120))
        .build();
    let fstar = p.optimum_value();
    let coef = 1.0 / (2.0 * alpha as f64) - l / 2.0;
    let mut prev_theta = coord.theta().to_vec();
    let mut v_first: Option<f64> = None;
    let mut v_last = 0.0f64;
    let mut count = 0usize;
    for k in 0..120 {
        let rec = coord.run_round(k);
        let diff = aquila::util::vecmath::diff_norm2_sq(coord.theta(), &prev_theta);
        prev_theta = coord.theta().to_vec();
        let v = rec.train_loss - fstar + coef * diff;
        if k >= 1 && v > 1e-12 {
            if v_first.is_none() {
                v_first = Some(v);
            }
            v_last = v;
            count = k;
        }
    }
    let v1 = v_first.unwrap();
    let steps = (count - 1).max(1) as f64;
    let geo_rate = (v_last / v1).powf(1.0 / steps);
    let theorem_rate = 1.0 - alpha as f64 * mu;
    // REPRODUCTION FINDING (EXPERIMENTS.md §Deviations): the measured
    // geometric rate is ~0.84 while Theorem 3 claims 1 − αμ ≈ 0.75 —
    // and the gap persists even at β = 0 (no skipping at all), so it is
    // the *quantization error* term the theorem's Assumption-3 step
    // absorbs too optimistically, not the device selection. We assert
    // the honest property: linear convergence with at least half the
    // claimed modulus.
    assert!(
        geo_rate < 1.0 - 0.5 * alpha as f64 * mu,
        "not even half of Theorem 3's modulus: {geo_rate} vs {theorem_rate}"
    );
    assert!(
        geo_rate > theorem_rate * 0.9,
        "contraction {geo_rate} suspiciously better than theory {theorem_rate} — check f*"
    );
}

/// Assumption 3's γ, estimated from actual AQUILA runs, is finite and
/// modest — supporting the paper's claim that the assumption is mild.
#[test]
fn gamma_estimates_are_modest() {
    // Simulate the quantity directly from device errors in a run-like
    // loop: γ = ‖ε‖²·M²/‖Σ_skip ε_m‖² with ε from the mid-tread bound.
    use aquila::quant::midtread::quantize_innovation_fused;
    use aquila::util::rng::Xoshiro256pp;
    let mut rng = Xoshiro256pp::seed_from_u64(105);
    let (d, m) = (128usize, 10usize);
    for _ in 0..20 {
        let mut global_err = vec![0.0f32; d];
        let mut skip_err = vec![0.0f32; d];
        let n_skip = 1 + rng.next_bounded(m as u64 - 1) as usize;
        for dev in 0..m {
            let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let (l2sq, linf) = aquila::util::vecmath::innovation_norms(&g, &q);
            let bits =
                aquila::quant::levels::aquila_level(l2sq.sqrt(), linf, d);
            let mut dq = vec![0.0f32; d];
            quantize_innovation_fused(&g, &q, bits, linf, &mut dq);
            for i in 0..d {
                let err = (g[i] - q[i]) - dq[i];
                global_err[i] += err / m as f32;
                if dev < n_skip {
                    skip_err[i] += err;
                }
            }
        }
        let ge = aquila::util::vecmath::norm2_sq(&global_err);
        let se = aquila::util::vecmath::norm2_sq(&skip_err);
        if let Some(gamma) = theory::estimate_gamma(ge, se, m) {
            assert!(gamma >= 1.0);
            assert!(gamma < 1e4, "γ blew up: {gamma}");
        }
    }
}

/// Lemma 1's bound dominates the actual skip-induced model deviation in
/// live AQUILA rounds.
#[test]
fn lemma1_bound_holds_in_live_rounds() {
    use aquila::quant::levels::aquila_level;
    use aquila::quant::midtread::{quantize_innovation_fused, QuantizedVec};
    use aquila::util::rng::Xoshiro256pp;
    let mut rng = Xoshiro256pp::seed_from_u64(107);
    let (d, m, alpha) = (64usize, 8usize, 0.1f64);
    for _ in 0..30 {
        // A synthetic "round": some devices skip; deviation = (α/M)‖Σ Δq_skip‖.
        let n_skip = 1 + rng.next_bounded(m as u64 - 1) as usize;
        let mut dq_sum = vec![0.0f32; d];
        let mut skipped: Vec<(f64, QuantizedVec)> = Vec::new();
        for _ in 0..n_skip {
            let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let (l2sq, linf) = aquila::util::vecmath::innovation_norms(&g, &q);
            let bits = aquila_level(l2sq.sqrt(), linf, d);
            let mut dq = vec![0.0f32; d];
            let out = quantize_innovation_fused(&g, &q, bits, linf, &mut dq);
            for (s, x) in dq_sum.iter_mut().zip(&dq) {
                *s += x;
            }
            skipped.push((l2sq.sqrt(), out.quantized));
        }
        let dev_sq = {
            let n = aquila::util::vecmath::norm2_sq(&dq_sum);
            (alpha / m as f64).powi(2) * n
        };
        let pairs: Vec<(f64, &QuantizedVec)> =
            skipped.iter().map(|(l2, q)| (*l2, q)).collect();
        let bound = theory::lemma1_bound(alpha, m, &pairs);
        assert!(
            dev_sq <= bound,
            "Lemma 1 violated: deviation {dev_sq} > bound {bound}"
        );
    }
}

/// Corollary 1 (non-convex form): the average squared gradient norm
/// over K rounds is ≤ 2ω₁/(αK) for feasible hyperparameters.
#[test]
fn corollary1_average_gradient_bound() {
    let p = Arc::new(QuadraticProblem::new(32, 6, 0.5, 2.0, 0.4, 109));
    let l = p.smoothness();
    let alpha = (0.4 / l) as f32;
    let gamma = 2.0;
    let beta = (theory::max_feasible_beta(l, alpha as f64, gamma) * 0.5) as f32;
    let mut coord = Session::builder(p.clone(), Arc::new(Aquila::new(beta)))
        .config(run_cfg(alpha, beta, 150))
        .build();
    let fstar = p.optimum_value();

    // Track ‖∇f(θᵏ)‖² directly.
    let mut grad_sq_sum = 0.0f64;
    let mut f1 = None;
    let mut theta_diff01 = 0.0f64;
    let mut prev_theta = coord.theta().to_vec();
    let mut ws = p.make_scratch();
    for k in 0..150 {
        // Global gradient at θᵏ before the round.
        let theta = coord.theta().to_vec();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for dev in 0..p.num_devices() {
            p.local_grad(dev, &theta, &mut g, &mut ws);
            aquila::util::vecmath::axpy(1.0 / p.num_devices() as f32, &g, &mut total);
        }
        if k >= 1 {
            grad_sq_sum += aquila::util::vecmath::norm2_sq(&total);
        }
        let rec = coord.run_round(k);
        if k == 1 {
            f1 = Some(rec.train_loss);
            theta_diff01 =
                aquila::util::vecmath::diff_norm2_sq(coord.theta(), &prev_theta);
        }
        prev_theta = theta;
    }
    let k_count = 149.0;
    let avg_grad_sq = grad_sq_sum / k_count;
    let omega1 = f1.unwrap() - fstar + beta as f64 * gamma / alpha as f64 * theta_diff01;
    let bound = 2.0 * omega1 / (alpha as f64 * k_count);
    assert!(
        avg_grad_sq <= bound * 1.05,
        "Corollary 1 violated: avg ‖∇f‖² = {avg_grad_sq} > {bound}"
    );
}
