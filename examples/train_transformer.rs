//! End-to-end driver: federated training of the JAX transformer LM
//! through the full three-layer stack.
//!
//! * L1 — the Pallas quantization kernel (inside the AOT artifacts),
//! * L2 — the transformer fwd/bwd lowered to HLO by `make artifacts`,
//! * L3 — this Rust coordinator: AQUILA's level rule + skip rule,
//!   byte-counted transport, aggregation.
//!
//! Trains `txf_small` (~1M params; set `MODEL=txf_tiny` for the smoke
//! config or `ROUNDS=...` to change the horizon) on the synthetic
//! Markov corpus with M = 8 devices, logging the loss curve and
//! comparing AQUILA's uplink bits against uncompressed FedAvg. The run
//! is recorded in EXPERIMENTS.md §E2E.
//!
//! Default β = 0.25: on this workload the paper's WT-2 choice (1.25)
//! violates the Corollary-1 feasibility condition and the skip rule
//! free-runs the server into divergence — see EXPERIMENTS.md
//! §Deviations D4.
//!
//! Usage: `make artifacts && cargo run --release --features xla --example train_transformer`
//! (the `xla` feature needs the vendored PJRT bindings; see `rust/Cargo.toml`)

use aquila::algorithms::{aquila::Aquila, fedavg::FedAvg, Algorithm};
use aquila::coordinator::{RunConfig, Session};
use aquila::data::text::{markov_corpus, shard_corpus, CorpusSpec};
use aquila::metrics::{bits_display, RunTrace};
use aquila::problems::GradientSource;
use aquila::runtime::{HloGradientSource, Manifest, PjrtRuntime};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&artifacts)?;
    let model_name: String = env_or("MODEL", "txf_small".to_string());
    let rounds: usize = env_or("ROUNDS", 300);
    let devices: usize = env_or("DEVICES", 8);
    let alpha: f32 = env_or("ALPHA", 1.0);
    let beta: f32 = env_or("BETA", 0.25);

    let model = manifest.model(&model_name)?;
    println!(
        "model {}: d = {} params, batch {} × seq {}, vocab {}",
        model.name, model.dim, model.batch, model.seq, model.vocab
    );

    // Synthetic Markov corpus (WikiText-2 stand-in; DESIGN.md §3).
    let corpus = markov_corpus(&CorpusSpec::wikitext2_like(400_000, 2026));
    let n_test = corpus.len() / 10;
    let heldout = corpus.slice(0, n_test);
    let train = corpus.slice(n_test, corpus.len());
    let shards = shard_corpus(&train, devices);

    let runtime = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());
    let src: Arc<dyn GradientSource> =
        Arc::new(HloGradientSource::new(&runtime, model, &shards, &heldout)?);

    let cfg = RunConfig {
        alpha,
        beta,
        rounds,
        eval_every: (rounds / 20).max(1),
        seed: 2026,
        threads: env_or("AQUILA_THREADS", 0),
        ..RunConfig::default()
    };

    println!("\n--- AQUILA (β = {beta}) ---");
    let t_aq = run_logged(src.clone(), Arc::new(Aquila::new(beta)), cfg.clone(), "aquila");

    println!("\n--- FedAvg (uncompressed reference) ---");
    let t_fed = run_logged(src, Arc::new(FedAvg), cfg, "fedavg");

    println!("\n=== summary ===");
    summarize("AQUILA", &t_aq);
    summarize("FedAvg", &t_fed);
    let saving = 100.0 * (1.0 - t_aq.total_bits() as f64 / t_fed.total_bits() as f64);
    println!("AQUILA uplink saving vs FedAvg: {saving:.1}%");

    std::fs::create_dir_all("results/e2e")?;
    t_aq.write_csv(Path::new("results/e2e/transformer_aquila.csv"))?;
    t_fed.write_csv(Path::new("results/e2e/transformer_fedavg.csv"))?;
    println!("loss curves written to results/e2e/");
    Ok(())
}

fn run_logged(
    src: Arc<dyn GradientSource>,
    algo: Arc<dyn Algorithm>,
    cfg: RunConfig,
    tag: &str,
) -> RunTrace {
    let rounds = cfg.rounds;
    let name = algo.name();
    let mut session = Session::builder(src, algo)
        .config(cfg)
        .dataset("markov-wt2")
        .split(&format!("iid-{tag}"))
        .build();
    let mut trace = RunTrace {
        algorithm: name.to_string(),
        dataset: "markov-wt2".to_string(),
        split: format!("iid-{tag}"),
        rounds: Vec::with_capacity(rounds),
    };
    let t0 = std::time::Instant::now();
    for k in 0..rounds {
        let rec = session.run_round(k);
        if rec.eval_loss.is_some() || k < 3 {
            println!(
                "round {k:>4}  train_loss {:>7.4}  ppl {:>8}  bits {:>12}  uploads {:>2}/{}  mean_b {:>4.1}",
                rec.train_loss,
                rec.perplexity
                    .map(|p| format!("{p:.2}"))
                    .unwrap_or_else(|| "-".to_string()),
                rec.cum_bits,
                rec.uploads,
                rec.uploads + rec.skips,
                rec.mean_level,
            );
        }
        trace.rounds.push(rec);
    }
    println!(
        "[{}] {} rounds in {:.1}s",
        name,
        rounds,
        t0.elapsed().as_secs_f64()
    );
    trace
}

fn summarize(name: &str, t: &RunTrace) {
    println!(
        "{name:<8} final loss {:.4}  final ppl {}  total bits {} Gb  uploads {}  skips {}",
        t.final_train_loss(),
        t.final_perplexity()
            .map(|p| format!("{p:.2}"))
            .unwrap_or_else(|| "-".into()),
        bits_display(t.total_bits()),
        t.total_uploads(),
        t.total_skips(),
    );
}
