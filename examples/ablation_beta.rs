//! Figure 4/5 ablation: the effect of the tuning factor β in AQUILA's
//! skip rule (eq. 8) on convergence, final metric, and total bits.
//!
//! ```bash
//! cargo run --release --example ablation_beta
//! ```
//!
//! Expected shape (paper Section V-D): moderate β barely affects the
//! final metric while sharply cutting bits; overly large β skips
//! essential uploads and degrades the model.

use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::metrics::bits_display;
use aquila::repro::{ablation_beta, metric_display};

fn main() {
    let betas = [0.0f32, 0.1, 0.25, 0.5, 1.25, 2.5, 5.0, 25.0];
    for ds in [DatasetKind::Cf10, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, false).scaled(0.3, 120);
        println!("\n=== {} (α = {}) ===", spec.row_label(), spec.alpha);
        println!(
            "{:>7} {:>12} {:>12} {:>8} {:>10}",
            "beta", "final", "bits(Gb)", "skip%", "loss"
        );
        for (beta, trace) in ablation_beta(&spec, &betas) {
            let total = trace.total_uploads() + trace.total_skips();
            println!(
                "{beta:>7.2} {:>12} {:>12} {:>7.1}% {:>10.4}",
                metric_display(&trace),
                bits_display(trace.total_bits()),
                100.0 * trace.total_skips() as f64 / total.max(1) as f64,
                trace.final_train_loss(),
            );
        }
    }
    println!("\n(paper's selections: β = 0.1 for CF-10, 0.25 for CF-100, 1.25 for WT-2)");
}
