//! Coordinator-as-a-service, end to end: the same seeded run executed
//! three ways.
//!
//! ```bash
//! cargo run --release --example service            # `verify` (loopback)
//! cargo run --release --example service tcp        # multi-process TCP
//! ```
//!
//! * `verify` (default, what CI runs) — executes the run in-process,
//!   then again through [`aquila::protocol::CoordinatorService`] over
//!   the in-process loopback transport with two client threads, and
//!   asserts the two [`RunTrace`]s are **bit-identical** (compared via
//!   their full `Debug` rendering, which prints every float exactly).
//!   On mismatch both traces are written to
//!   `service_trace_{inproc,loopback}.txt` and the process exits 1.
//! * `tcp` — binds a real TCP coordinator and spawns two child
//!   processes of this same binary (`client` mode) over localhost; one
//!   child goes silent after the first round, so the run must finish
//!   with ≥ 1 straggler detected through heartbeat expiry.
//! * `client <addr> [silent-after-N]` — the child role for `tcp`.

use aquila::algorithms::aquila::Aquila;
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::metrics::RunTrace;
use aquila::problems::GradientSource;
use aquila::protocol::{
    CoordinatorService, DeviceClient, LoopbackHub, ServeSpec, TcpConnection, TcpTransport,
};
use aquila::repro;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

/// The shared experiment cell — every mode (and every spawned child)
/// reconstructs the identical problem from this spec.
fn spec() -> ExperimentSpec {
    let mut s = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false).scaled(0.02, 8);
    s.devices = 4;
    s
}

fn serve_spec() -> ServeSpec {
    ServeSpec {
        clients: 2,
        heartbeat_ms: 50,
        heartbeat_timeout_ms: 1_000,
        // Short round deadline: the rejoin-aware collect loop waits for
        // a dead client's devices until the deadline, and the `tcp`
        // mode's silent client never comes back.
        round_timeout_ms: 2_000,
        accept_timeout_ms: 30_000,
        ..ServeSpec::default()
    }
}

/// Serve the spec's session over an in-process loopback hub with
/// `clients` client threads dialing it.
fn run_served(clients: usize) -> RunTrace {
    let s = spec();
    let mut service = CoordinatorService::new(
        repro::session_for(&s, Arc::new(Aquila::new(s.beta))).build(),
        ServeSpec { clients, ..serve_spec() },
    );
    let mut hub = LoopbackHub::new();
    let mut handles = Vec::new();
    for _ in 0..clients {
        let dialer = hub.dialer();
        let s = s.clone();
        handles.push(std::thread::spawn(move || {
            let problem: Arc<dyn GradientSource> = s.build_problem().into();
            let masks = repro::masks_for(&s, problem.as_ref());
            let algo = Arc::new(Aquila::new(s.beta));
            let client = DeviceClient::new(problem, algo, s.run_config(), masks).heartbeat_ms(50);
            let mut conn = dialer.connect();
            client.run(&mut conn).expect("loopback client");
        }));
    }
    let trace = service.run(&mut hub).expect("service run");
    for h in handles {
        h.join().expect("client thread");
    }
    trace
}

fn cmd_verify() -> ExitCode {
    let s = spec();
    println!(
        "verify: {} — {} devices, {} rounds, in-process vs loopback service",
        s.row_label(),
        s.devices,
        s.rounds
    );
    let inproc = repro::session_for(&s, Arc::new(Aquila::new(s.beta))).build().run();
    let served = run_served(2);
    let a = format!("{:#?}", inproc.rounds);
    let b = format!("{:#?}", served.rounds);
    if a == b {
        println!(
            "OK: {} rounds bit-identical ({} uplink bits, final loss {})",
            inproc.rounds.len(),
            inproc.total_bits(),
            inproc.final_train_loss()
        );
        ExitCode::SUCCESS
    } else {
        std::fs::write("service_trace_inproc.txt", &a).expect("write artifact");
        std::fs::write("service_trace_loopback.txt", &b).expect("write artifact");
        eprintln!(
            "MISMATCH: traces differ; wrote service_trace_inproc.txt / \
             service_trace_loopback.txt"
        );
        ExitCode::FAILURE
    }
}

fn cmd_tcp() -> ExitCode {
    let s = spec();
    let mut transport = TcpTransport::bind("127.0.0.1:0").expect("bind");
    let addr = transport.local_addr().expect("local addr").to_string();
    println!("tcp: coordinator on {addr}, spawning 2 client processes (one goes silent)");
    let exe = std::env::current_exe().expect("current exe");
    let healthy = std::process::Command::new(&exe)
        .args(["client", &addr])
        .spawn()
        .expect("spawn healthy client");
    let silent = std::process::Command::new(&exe)
        .args(["client", &addr, "silent-after-1"])
        .spawn()
        .expect("spawn silent client");

    let mut service = CoordinatorService::new(
        repro::session_for(&s, Arc::new(Aquila::new(s.beta))).build(),
        serve_spec(),
    );
    let trace = service.run(&mut transport).expect("service run");
    let healthy = healthy.wait().expect("wait healthy");
    let silent = silent.wait().expect("wait silent");
    println!(
        "run complete: {} rounds, {} stragglers, client exits {healthy} / {silent}",
        trace.rounds.len(),
        trace.total_stragglers()
    );
    if trace.total_stragglers() == 0 {
        eprintln!("FAIL: the silent client should have been detected via heartbeat expiry");
        return ExitCode::FAILURE;
    }
    if !healthy.success() || !silent.success() {
        eprintln!("FAIL: a client process exited nonzero");
        return ExitCode::FAILURE;
    }
    println!("OK: silent client's devices became stragglers; run still completed");
    ExitCode::SUCCESS
}

fn cmd_client(addr: &str, silent_after: Option<usize>) -> ExitCode {
    let s = spec();
    let problem: Arc<dyn GradientSource> = s.build_problem().into();
    let masks = repro::masks_for(&s, problem.as_ref());
    let algo = Arc::new(Aquila::new(s.beta));
    let mut client = DeviceClient::new(problem, algo, s.run_config(), masks).heartbeat_ms(50);
    if let Some(n) = silent_after {
        client = client.silent_after(n);
    }
    let mut conn = TcpConnection::connect(addr, Duration::from_secs(10)).expect("connect");
    match client.run(&mut conn) {
        Ok(rep) => {
            println!(
                "client {}: devices {}..{}, {} round(s) served",
                rep.client_id, rep.devices.start, rep.devices.end, rep.rounds_served
            );
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("client failed: {e}");
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        None | Some("verify") => cmd_verify(),
        Some("tcp") => cmd_tcp(),
        Some("client") => {
            let Some(addr) = args.get(1) else {
                eprintln!("usage: service client ADDR [silent-after-N]");
                return ExitCode::FAILURE;
            };
            let silent = match args.get(2) {
                Some(a) => a.strip_prefix("silent-after-").and_then(|n| n.parse().ok()),
                None => None,
            };
            cmd_client(addr, silent)
        }
        Some(other) => {
            eprintln!("unknown mode '{other}' (expected: verify | tcp | client)");
            ExitCode::FAILURE
        }
    }
}
