//! Heterogeneous-capacity demo (paper Section V-C / Table III): half
//! the devices hold the full model, half a HeteroFL-style 50% submodel.
//!
//! ```bash
//! cargo run --release --example heterogeneous
//! ```

use aquila::algorithms::{aquila::Aquila, qsgd::QsgdAlgo, Algorithm};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::Session;
use aquila::hetero::{half_half_masks, CapacityMask};
use aquila::metrics::bits_display;
use aquila::problems::GradientSource;
use aquila::repro::metric_display;
use std::sync::Arc;

fn main() {
    let spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, false).scaled(0.3, 120);
    let problem: Arc<dyn GradientSource> = spec.build_problem().into();
    let layout = problem.layout();

    // The 100%–50% split of the paper's heterogeneous tables.
    let masks = half_half_masks(&layout, problem.num_devices(), 0.5);
    let full_d = layout.dim();
    let reduced = CapacityMask::from_layout(&layout, 0.5);
    println!(
        "model d = {full_d}; 50%-capacity devices train {} params ({:.1}%)\n",
        reduced.support(),
        100.0 * reduced.support() as f64 / full_d as f64
    );

    let algos: Vec<(&str, Arc<dyn Algorithm>)> = vec![
        ("QSGD-8b", Arc::new(QsgdAlgo::new(8))),
        ("AQUILA", Arc::new(Aquila::new(spec.beta))),
    ];
    println!(
        "{:<10} {:>12} {:>14} {:>14}",
        "algorithm", "accuracy", "homog(Gb)", "hetero(Gb)"
    );
    for (name, algo) in algos {
        let t_homo = Session::builder(problem.clone(), algo.clone())
            .config(spec.run_config())
            .dataset(spec.dataset.name())
            .split("homog")
            .build()
            .run();
        let t_het = Session::builder(problem.clone(), algo)
            .config(spec.run_config())
            .masks(masks.clone())
            .dataset(spec.dataset.name())
            .split("hetero")
            .build()
            .run();
        println!(
            "{name:<10} {:>11}% {:>14} {:>14}",
            metric_display(&t_het),
            bits_display(t_homo.total_bits()),
            bits_display(t_het.total_bits()),
        );
    }
    println!("\nHetero devices upload only their submodel support — the byte counts");
    println!("shrink accordingly while the server scatter-adds into the full model.");
}
