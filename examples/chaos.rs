//! Chaos-hardened serving, end to end: the seeded run must survive
//! injected transport faults and a coordinator kill without changing
//! a single bit of the trace.
//!
//! ```bash
//! cargo run --release --example chaos           # `verify` (loopback chaos)
//! cargo run --release --example chaos kill      # multi-process kill+resume
//! ```
//!
//! * `verify` (default, CI-gated) — executes the run in-process, then
//!   again through [`aquila::protocol::CoordinatorService`] over the
//!   loopback transport wrapped in a [`aquila::protocol::ChaosTransport`]
//!   injecting *every* fault kind at once (drops, stalls, partial
//!   frames, corruption, duplicates, accept failures), with two
//!   reconnecting client threads. The two `RunTrace`s must be
//!   **bit-identical**; on mismatch both are written to
//!   `chaos_trace_{inproc,served}.txt` and the process exits 1.
//! * `kill` (CI-gated) — spawns a real coordinator process over TCP
//!   that checkpoints every round and dies after round 2, plus two
//!   reconnecting client processes. A second coordinator process is
//!   then started with `--resume` semantics on the same address; the
//!   clients rejoin it, and the stitched head+tail trace must equal
//!   the uninterrupted in-process run bit for bit, with zero
//!   stragglers in the resumed rounds. Mismatches land in
//!   `chaos_trace_{expected,got}.txt`.
//! * `coord <addr> <ckpt> <out> [halt-after-N | resume]` and
//!   `client <addr>` — the child roles for `kill`.

use aquila::algorithms::aquila::Aquila;
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::checkpoint::Checkpoint;
use aquila::protocol::{
    ChaosSpec, CoordinatorService, DeviceClient, LoopbackHub, ServeSpec, TcpDialer, TcpTransport,
};
use aquila::repro;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

/// The round the pre-kill coordinator dies after (it serves rounds
/// `0..=HALT`; the resumed one serves `HALT + 1..`).
const HALT: usize = 2;

/// The shared experiment cell — every mode (and every spawned child)
/// reconstructs the identical problem from this spec.
fn spec() -> ExperimentSpec {
    let mut s = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false).scaled(0.02, 8);
    s.devices = 4;
    s
}

fn serve_spec() -> ServeSpec {
    ServeSpec {
        clients: 2,
        heartbeat_ms: 50,
        heartbeat_timeout_ms: 1_000,
        round_timeout_ms: 10_000,
        accept_timeout_ms: 30_000,
        ..ServeSpec::default()
    }
}

/// Every fault kind at once — the stress mix the verify mode runs
/// under. Recovery must finish inside the round deadline for each.
fn chaos_mix() -> ChaosSpec {
    let s = "drop=0.06,stall=0.2,stall_ms=3,partial=0.03,corrupt=0.04,dup=0.15,accept=0.3,seed=24";
    ChaosSpec::parse(s).expect("chaos grammar")
}

/// A client that treats a lost connection as a rejoin, not a failure.
fn resilient_client(s: &ExperimentSpec) -> DeviceClient {
    repro::client_for(s, Arc::new(Aquila::new(s.beta)))
        .heartbeat_ms(50)
        .reconnect(60, 10, 200)
        .idle_timeout_ms(750)
}

fn inprocess(s: &ExperimentSpec) -> aquila::metrics::RunTrace {
    repro::session_for(s, Arc::new(Aquila::new(s.beta))).build().run()
}

fn cmd_verify() -> ExitCode {
    let s = spec();
    let chaos = chaos_mix();
    println!(
        "verify: {} — {} rounds in-process vs chaos-served loopback ({chaos})",
        s.row_label(),
        s.rounds
    );
    let inproc = inprocess(&s);
    let hub = LoopbackHub::new();
    let mut handles = Vec::new();
    for _ in 0..2 {
        let dialer = hub.dialer();
        let s = s.clone();
        handles.push(std::thread::spawn(move || {
            resilient_client(&s).run_with(&dialer).expect("resilient loopback client");
        }));
    }
    let mut service = CoordinatorService::new(
        repro::session_for(&s, Arc::new(Aquila::new(s.beta))).build(),
        serve_spec(),
    );
    let mut transport = chaos.wrap_transport(Box::new(hub));
    let served = service.run(&mut transport).expect("chaos-served run");
    for h in handles {
        h.join().expect("client thread");
    }
    let a = format!("{:#?}", inproc.rounds);
    let b = format!("{:#?}", served.rounds);
    if a == b {
        println!(
            "OK: {} rounds bit-identical under chaos ({} uplink bits, final loss {})",
            inproc.rounds.len(),
            inproc.total_bits(),
            inproc.final_train_loss()
        );
        ExitCode::SUCCESS
    } else {
        std::fs::write("chaos_trace_inproc.txt", &a).expect("write artifact");
        std::fs::write("chaos_trace_served.txt", &b).expect("write artifact");
        eprintln!("MISMATCH: traces differ; wrote chaos_trace_inproc.txt / chaos_trace_served.txt");
        ExitCode::FAILURE
    }
}

fn cmd_kill() -> ExitCode {
    let s = spec();
    let want = inprocess(&s);
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    let ckpt = tmp.join(format!("chaos_kill_{pid}.ckpt"));
    let head_out = tmp.join(format!("chaos_kill_{pid}_head.txt"));
    let tail_out = tmp.join(format!("chaos_kill_{pid}_tail.txt"));
    let exe = std::env::current_exe().expect("current exe");
    println!(
        "kill: coordinator serves {} rounds over TCP, dies after round {HALT}, resumes from \
         its checkpoint",
        s.rounds
    );

    let mut coord = std::process::Command::new(&exe)
        .args(["coord", "127.0.0.1:0"])
        .arg(&ckpt)
        .arg(&head_out)
        .arg(format!("halt-after-{HALT}"))
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn coordinator");
    let mut reader = BufReader::new(coord.stdout.take().expect("coordinator stdout"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("read coordinator addr");
    let addr = line
        .trim()
        .strip_prefix("ADDR ")
        .expect("coordinator prints ADDR first")
        .to_string();
    // Keep draining the pipe so the child never blocks on a full buffer.
    std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
        print!("{rest}");
    });
    let clients: Vec<_> = (0..2)
        .map(|_| {
            std::process::Command::new(&exe)
                .args(["client", &addr])
                .spawn()
                .expect("spawn client")
        })
        .collect();

    let st1 = coord.wait().expect("wait coordinator");
    if !st1.success() {
        eprintln!("FAIL: pre-kill coordinator exited nonzero");
        return ExitCode::FAILURE;
    }
    println!("coordinator died after round {HALT}; restarting on {addr} with --resume");
    let mut coord2 = std::process::Command::new(&exe)
        .args(["coord", &addr])
        .arg(&ckpt)
        .arg(&tail_out)
        .arg("resume")
        .spawn()
        .expect("spawn resumed coordinator");
    let st2 = coord2.wait().expect("wait resumed coordinator");
    let mut ok = st2.success();
    for c in clients {
        ok &= c.wait().expect("wait client").success();
    }
    if !ok {
        eprintln!("FAIL: a child process exited nonzero");
        return ExitCode::FAILURE;
    }

    let head = std::fs::read_to_string(&head_out).expect("head trace");
    let tail = std::fs::read_to_string(&tail_out).expect("tail trace");
    let want_head = format!("start=0\n{:#?}", &want.rounds[..HALT + 1]);
    let want_tail = format!("start={}\n{:#?}", HALT + 1, &want.rounds[HALT + 1..]);
    for p in [&ckpt, &head_out, &tail_out] {
        let _ = std::fs::remove_file(p);
    }
    if head != want_head || tail != want_tail {
        std::fs::write("chaos_trace_expected.txt", format!("{want_head}\n---\n{want_tail}"))
            .expect("write artifact");
        std::fs::write("chaos_trace_got.txt", format!("{head}\n---\n{tail}"))
            .expect("write artifact");
        eprintln!("MISMATCH: stitched trace differs; wrote chaos_trace_{{expected,got}}.txt");
        return ExitCode::FAILURE;
    }
    println!(
        "OK: head ({} rounds) + resumed tail ({} rounds) bit-identical to the uninterrupted run",
        HALT + 1,
        want.rounds.len() - HALT - 1
    );
    ExitCode::SUCCESS
}

fn cmd_coord(addr: &str, ckpt: &Path, out: &Path, mode: Option<&str>) -> ExitCode {
    let s = spec();
    let mut service = CoordinatorService::new(
        repro::session_for(&s, Arc::new(Aquila::new(s.beta))).build(),
        serve_spec(),
    )
    .checkpoint_to(ckpt.to_path_buf(), 1);
    let mut start = 0usize;
    match mode {
        Some(m) if m.starts_with("halt-after-") => {
            let n: usize = m["halt-after-".len()..].parse().expect("halt round");
            service = service.halt_after_round(n);
        }
        Some("resume") => {
            let c = Checkpoint::load(ckpt).expect("load checkpoint");
            start = service.resume_from(&c).expect("resume from checkpoint");
        }
        Some(other) => {
            eprintln!("unknown coord mode '{other}' (expected: halt-after-N | resume)");
            return ExitCode::FAILURE;
        }
        None => {}
    }
    let mut transport = TcpTransport::bind(addr).expect("bind");
    println!("ADDR {}", transport.local_addr().expect("local addr"));
    let trace = match service.run(&mut transport) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("coordinator failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    std::fs::write(out, format!("start={start}\n{:#?}", trace.rounds)).expect("write trace");
    if start > 0 && trace.rounds.iter().any(|r| r.stragglers != 0) {
        eprintln!("FAIL: resumed run manufactured stragglers");
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

fn cmd_client(addr: &str) -> ExitCode {
    let s = spec();
    let dialer = TcpDialer::new(addr, Duration::from_secs(5));
    match resilient_client(&s).run_with(&dialer) {
        Ok(rep) => {
            println!(
                "client {}: devices {}..{}, {} round(s) served",
                rep.client_id, rep.devices.start, rep.devices.end, rep.rounds_served
            );
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("client failed: {e}");
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        None | Some("verify") => cmd_verify(),
        Some("kill") => cmd_kill(),
        Some("coord") => {
            if args.len() < 4 {
                eprintln!("usage: chaos coord ADDR CKPT OUT [halt-after-N | resume]");
                return ExitCode::FAILURE;
            }
            cmd_coord(
                &args[1],
                Path::new(&args[2]),
                Path::new(&args[3]),
                args.get(4).map(|s| s.as_str()),
            )
        }
        Some("client") => {
            let Some(addr) = args.get(1) else {
                eprintln!("usage: chaos client ADDR");
                return ExitCode::FAILURE;
            };
            cmd_client(addr)
        }
        Some(other) => {
            eprintln!("unknown mode '{other}' (expected: verify | kill | coord | client)");
            ExitCode::FAILURE
        }
    }
}
