//! Quickstart: AQUILA vs uncompressed FedAvg on a 10-device synthetic
//! classification task, in ~5 seconds on a laptop.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the two knobs the paper contributes — the adaptive
//! quantization level (eq. 19) and the device-selection skip rule
//! (eq. 8) — and the resulting uplink savings at matched accuracy.

use aquila::algorithms::{aquila::Aquila, fedavg::FedAvg, qsgd::QsgdAlgo};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::metrics::bits_display;
use aquila::repro::{metric_display, run_cell, session_for};
use aquila::selection::SelectionSpec;
use std::sync::Arc;

fn main() {
    // A CIFAR-10-like Gaussian-mixture task, 10 devices, IID split
    // (DESIGN.md §3 documents the substitution).
    let spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false).scaled(0.3, 120);
    println!(
        "task: {} — {} devices, {} rounds, α = {}, β = {}\n",
        spec.row_label(),
        spec.devices,
        spec.rounds,
        spec.alpha,
        spec.beta
    );

    // The AQUILA+rand5 row runs through the builder with an explicit
    // selection strategy — the same thing `--select random-k:5` does.
    let aquila_rand5 = session_for(&spec, Arc::new(Aquila::new(spec.beta)))
        .selection_spec(SelectionSpec::RandomK(5))
        .build()
        .run();

    println!("{:<12} {:>10} {:>12} {:>9} {:>8}", "algorithm", "accuracy", "uplink(Gb)", "uploads", "skip%");
    for (name, trace) in [
        ("FedAvg", run_cell(&spec, Arc::new(FedAvg))),
        ("QSGD-8b", run_cell(&spec, Arc::new(QsgdAlgo::new(8)))),
        ("AQUILA", run_cell(&spec, Arc::new(Aquila::new(spec.beta)))),
        ("AQUILA+rand5", aquila_rand5),
    ] {
        let total = trace.total_uploads() + trace.total_skips();
        println!(
            "{name:<12} {:>9}% {:>12} {:>9} {:>7.1}%",
            metric_display(&trace),
            bits_display(trace.total_bits()),
            trace.total_uploads(),
            100.0 * trace.total_skips() as f64 / total.max(1) as f64,
        );
    }
    println!("\nAQUILA transmits adaptively-quantized gradient innovations only when");
    println!("they matter (eq. 8), at the deviation-minimizing level (eq. 19).");
}
