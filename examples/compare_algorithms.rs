//! One full Table-II-style row: all seven algorithms on the same
//! federated task, at reduced scale so it finishes in under a minute.
//!
//! ```bash
//! cargo run --release --example compare_algorithms [dataset] [split]
//! # e.g.  cargo run --release --example compare_algorithms wt2 iid
//! ```

use aquila::algorithms::table_suite;
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::metrics::bits_display;
use aquila::repro::{metric_display, run_cell};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ds = args
        .get(1)
        .and_then(|s| DatasetKind::parse(s))
        .unwrap_or(DatasetKind::Cf10);
    let split = args
        .get(2)
        .and_then(|s| SplitKind::parse(s))
        .unwrap_or(SplitKind::NonIid);
    let spec = ExperimentSpec::new(ds, split, false).scaled(0.3, 150);
    println!(
        "row: {} — M = {}, {} rounds, α = {}, β = {}\n",
        spec.row_label(),
        spec.devices,
        spec.rounds,
        spec.alpha,
        spec.beta
    );
    println!(
        "{:<12} {:>10} {:>12} {:>9} {:>8} {:>8}",
        "algorithm", "acc/ppl", "uplink(Gb)", "uploads", "skip%", "mean_b"
    );
    let mut aquila_bits = 0u64;
    let mut rows = Vec::new();
    for algo in table_suite(spec.beta) {
        let trace = run_cell(&spec, algo.clone());
        let total = trace.total_uploads() + trace.total_skips();
        let mean_b: f64 = {
            let levels: Vec<f64> = trace
                .rounds
                .iter()
                .filter(|r| r.mean_level > 0.0)
                .map(|r| r.mean_level)
                .collect();
            levels.iter().sum::<f64>() / levels.len().max(1) as f64
        };
        println!(
            "{:<12} {:>10} {:>12} {:>9} {:>7.1}% {:>8.2}",
            algo.name(),
            metric_display(&trace),
            bits_display(trace.total_bits()),
            trace.total_uploads(),
            100.0 * trace.total_skips() as f64 / total.max(1) as f64,
            mean_b,
        );
        if algo.name() == "AQUILA" {
            aquila_bits = trace.total_bits();
        }
        rows.push((algo.name().to_string(), trace.total_bits()));
    }
    println!();
    for (name, bits) in rows {
        if name != "AQUILA" && bits > 0 {
            println!(
                "AQUILA saves {:>5.1}% vs {name}",
                100.0 * (1.0 - aquila_bits as f64 / bits as f64)
            );
        }
    }
}
