//! Simulated network scenarios: the same federated task under four
//! link populations, with straggler deadlines and time-to-accuracy.
//!
//! ```bash
//! cargo run --release --example network_scenarios
//! ```
//!
//! AQUILA's claim is that adaptive quantization must survive
//! *non-uniform device participation*. This example runs one task over
//! increasingly hostile networks (`ideal` → `lan` → `edge-mix` →
//! `cellular` with a round deadline) and prints the new axes the
//! `transport::scenario` subsystem measures: simulated wall-clock
//! (`sim_time`), straggler counts, downlink bits, and
//! `time_to_loss` — the time-to-accuracy companion of `bits_to_loss`.

use aquila::algorithms::aquila::Aquila;
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::metrics::bits_display;
use aquila::repro::{metric_display, session_for};
use aquila::selection::SelectionSpec;
use aquila::transport::scenario::NetworkSpec;
use std::sync::Arc;

fn main() {
    let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false).scaled(0.3, 80);
    println!(
        "task: {} — {} devices, {} rounds, α = {}, β = {}\n",
        spec.row_label(),
        spec.devices,
        spec.rounds,
        spec.alpha,
        spec.beta
    );

    // Target loss for the time/bits-to-accuracy columns: what the
    // ideal-network run reaches after ~3/4 of its rounds.
    let baseline = session_for(&spec, Arc::new(Aquila::new(spec.beta))).build().run();
    let target = baseline.rounds[baseline.rounds.len() * 3 / 4].train_loss;

    println!(
        "{:<34} {:>8} {:>10} {:>9} {:>10} {:>11} {:>11}",
        "network", "acc%", "uplink(Gb)", "stragglers", "sim_time(s)", "bits→loss", "time→loss(s)"
    );
    // Cellular latency alone spans 50–300 ms and the 4-bit payloads
    // cross in ~10 ms even at 1 Mbps, so a 150 ms deadline turns the
    // high-latency tail of the fleet into stragglers.
    for net in [
        "ideal",
        "lan",
        "edge-mix",
        "cellular:deadline=0.15",
        "cellular:deadline=0.15,policy=late",
    ] {
        let network = NetworkSpec::parse(net).expect("example specs are valid");
        spec.network = network;
        // Availability-aware selection over a 4-round / 3-duty cycle —
        // the cohort shrinks when devices are down, stressing the
        // deadline window further.
        let trace = session_for(&spec, Arc::new(Aquila::new(spec.beta)))
            .selection_spec(SelectionSpec::Availability {
                period: 4,
                duty: 3,
                cap: None,
            })
            .build()
            .run();
        println!(
            "{net:<34} {:>8} {:>10} {:>9} {:>10.2} {:>11} {:>11}",
            metric_display(&trace),
            bits_display(trace.total_bits()),
            trace.total_stragglers(),
            trace.total_sim_time(),
            trace
                .bits_to_loss(target)
                .map(bits_display)
                .unwrap_or_else(|| "—".into()),
            trace
                .time_to_loss(target)
                .map(|t| format!("{t:.2}"))
                .unwrap_or_else(|| "—".into()),
        );
    }
    println!("\nsim_time is monotone within a run; a finite deadline turns slow uplinks");
    println!("into stragglers (dropped by default, folded late under policy=late).");
}
