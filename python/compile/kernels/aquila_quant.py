"""L1: the fused AQUILA quantization step as Pallas kernels.

Two block-tiled streaming kernels over the (implicit) gradient
innovation ``v = g - q_prev``:

* **pass 1** (`_norms_kernel`) — per-block partial reductions of
  ``sum(v^2)`` and ``max|v|``; finalized by a tiny jnp reduction over the
  grid outputs. This is where the eq.-19 level decision's inputs come
  from.
* **pass 2** (`_quant_kernel`) — elementwise mid-tread quantize +
  dequantize at the chosen level, emitting the reconstructed ``dq``
  block plus per-block partials of ``||dq||^2`` and ``||eps||^2`` (the
  two sides of the eq.-8 skip rule).

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks are
``BLOCK = 2048`` f32 lanes (8 KiB per operand — comfortably double-
bufferable in ~16 MiB VMEM at 3 live operands/block); both passes are
memory-bound streaming kernels, one HBM read of ``g``/``q_prev`` per
pass and one write of ``dq``.  ``interpret=True`` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so the kernels lower to
plain HLO (numerically identical; see /opt/xla-example/README.md).

The scalar epilogue (level selection, step sizes) is plain jnp glue in
:func:`device_step` so the whole client computation lowers into a single
HLO module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 2048


def _pad_to_block(x: jnp.ndarray) -> jnp.ndarray:
    d = x.shape[0]
    rem = (-d) % BLOCK
    if rem:
        x = jnp.pad(x, (0, rem))
    return x


def _norms_kernel(g_ref, q_ref, l2_ref, linf_ref):
    """Per-block partials: l2_ref[i] = sum(v^2), linf_ref[i] = max|v|."""
    v = g_ref[...] - q_ref[...]
    l2_ref[0] = jnp.sum(v * v)
    linf_ref[0] = jnp.max(jnp.abs(v))


def innovation_norms(g: jnp.ndarray, q_prev: jnp.ndarray):
    """Pass 1: (sum(v^2), max|v|) of the innovation via Pallas."""
    assert g.shape == q_prev.shape and g.ndim == 1
    gp = _pad_to_block(g.astype(jnp.float32))
    qp = _pad_to_block(q_prev.astype(jnp.float32))
    grid = gp.shape[0] // BLOCK
    l2_parts, linf_parts = pl.pallas_call(
        _norms_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=True,
    )(gp, qp)
    return jnp.sum(l2_parts), jnp.max(linf_parts)


def _quant_kernel(d: int, g_ref, q_ref, scale_ref, dq_ref, dqsq_ref, errsq_ref):
    """Per-block mid-tread quantize/dequantize + error partials.

    ``scale_ref`` broadcasts 4 scalars to every block:
      [0] inv_step = 1/(2 tau R)   (0 when R = 0)
      [1] step     = 2 tau R
      [2] R
      [3] max_code = 2^b - 1

    ``d`` (static) masks the padded tail lanes out of the partial sums:
    a padded zero would otherwise mid-tread to a grid point (e.g. +R at
    b = 1) and pollute ``||dq||^2`` / ``||eps||^2``.
    """
    v = g_ref[...] - q_ref[...]
    inv_step = scale_ref[0]
    step = scale_ref[1]
    r = scale_ref[2]
    max_code = scale_ref[3]
    psi = jnp.floor((v + r) * inv_step + 0.5)
    psi = jnp.clip(psi, 0.0, max_code)
    dq = step * psi - jnp.where(max_code > 0.0, r, 0.0)
    # R = 0 ⇒ inv_step = step = 0 ⇒ dq = -r = 0 (r is 0 too).
    err = v - dq
    idx = pl.program_id(0) * BLOCK + jax.lax.iota(jnp.int32, BLOCK)
    valid = idx < d
    dq = jnp.where(valid, dq, 0.0)
    err = jnp.where(valid, err, 0.0)
    dq_ref[...] = dq
    dqsq_ref[0] = jnp.sum(dq * dq)
    errsq_ref[0] = jnp.sum(err * err)


def quantize_innovation(g: jnp.ndarray, q_prev: jnp.ndarray, bits: jnp.ndarray, linf):
    """Pass 2 at (traced) level ``bits`` and range ``linf``.

    Returns ``(dq, dq_norm_sq, err_norm_sq)``.
    """
    d = g.shape[0]
    gp = _pad_to_block(g.astype(jnp.float32))
    qp = _pad_to_block(q_prev.astype(jnp.float32))
    grid = gp.shape[0] // BLOCK
    r = jnp.asarray(linf, jnp.float32)
    nlevels = (jnp.power(2.0, bits.astype(jnp.float32)) - 1.0).astype(jnp.float32)
    tau = 1.0 / nlevels
    step = 2.0 * tau * r
    inv_step = jnp.where(step > 0.0, 1.0 / step, 0.0)
    scales = jnp.stack([inv_step, step, jnp.where(r > 0, r, 0.0), nlevels])
    dq, dqsq, errsq = pl.pallas_call(
        functools.partial(_quant_kernel, d),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gp.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=True,
    )(gp, qp, scales)
    return dq[:d], jnp.sum(dqsq), jnp.sum(errsq)


@functools.partial(jax.jit, static_argnames=())
def device_step(g: jnp.ndarray, q_prev: jnp.ndarray):
    """The fused AQUILA client computation, Pallas edition.

    ``(dq, range, bits, dq_norm_sq, err_norm_sq)`` — same contract as
    ``ref.device_step`` and the Rust hot path; the artifact
    ``aquila_quant_<d>.hlo.txt`` is this function lowered at a fixed
    ``d``.
    """
    d = g.shape[0]
    l2sq, linf = innovation_norms(g, q_prev)
    bits = ref.aquila_level(jnp.sqrt(l2sq.astype(jnp.float64)), linf, d)
    dq, dq_norm_sq, err_norm_sq = quantize_innovation(g, q_prev, bits, linf)
    return dq, linf, bits, dq_norm_sq, err_norm_sq
