"""Pure-jnp oracle for the AQUILA quantization step.

This is the correctness reference for the L1 Pallas kernel
(`aquila_quant.py`) and mirrors (bit-for-bit, up to f32 rounding) the
Rust hot path in `rust/src/quant/midtread.rs`:

* deterministic mid-tread quantizer (paper Definition 2):
  ``psi_i = floor((v_i + R) / (2 tau R) + 1/2)``, ``tau = 1/(2^b - 1)``,
  ``R = ||v||_inf``;
* reconstruction (Lemma 4): ``dq = 2 tau R psi - R``;
* AQUILA's optimal level (Theorem 1, eq. 19):
  ``b* = ceil(log2(R sqrt(d) / ||v||_2 + 1))``;
* the fused device step returning everything the skip rule (eq. 8)
  needs.
"""

from __future__ import annotations

import jax.numpy as jnp

MAX_BITS = 32


def innovation_norms(g: jnp.ndarray, q_prev: jnp.ndarray):
    """(||g - q||_2^2, ||g - q||_inf) without materializing twice."""
    v = g - q_prev
    return jnp.sum(v * v), jnp.max(jnp.abs(v)) if v.size else (0.0, 0.0)


def aquila_level(l2: jnp.ndarray, linf: jnp.ndarray, d: int) -> jnp.ndarray:
    """eq. 19; returns an int32 scalar in [1, 32].

    Degenerate zero innovation maps to level 1 (matching the Rust
    implementation).
    """
    ratio = jnp.where(l2 > 0.0, linf * jnp.sqrt(float(d)) / jnp.maximum(l2, 1e-38), 0.0)
    b = jnp.ceil(jnp.log2(ratio + 1.0))
    return jnp.clip(b, 1, MAX_BITS).astype(jnp.int32)


def quantize(v: jnp.ndarray, bits: jnp.ndarray, range_: jnp.ndarray | None = None):
    """Mid-tread quantization of ``v`` at (possibly traced) level
    ``bits``. Returns ``(psi, dq, range)`` with psi float32 (codes are
    exact integers below 2^24 in f32; the exported HLO kernel uses f64
    internally like the Rust path for larger levels).
    """
    v = v.astype(jnp.float32)
    r = jnp.max(jnp.abs(v)) if range_ is None else range_
    nlevels = jnp.power(2.0, bits.astype(jnp.float64)) - 1.0  # 2^b - 1
    tau = 1.0 / nlevels
    step = 2.0 * tau * r.astype(jnp.float64)
    inv_step = jnp.where(step > 0.0, 1.0 / step, 0.0)
    v64 = v.astype(jnp.float64)
    psi = jnp.floor((v64 + r.astype(jnp.float64)) * inv_step + 0.5)
    psi = jnp.clip(psi, 0.0, nlevels)
    dq = jnp.where(r > 0.0, step * psi - r.astype(jnp.float64), 0.0)
    return psi, dq.astype(jnp.float32), r


def device_step(g: jnp.ndarray, q_prev: jnp.ndarray):
    """The fused AQUILA client computation (reference semantics).

    Returns ``(dq, range, bits, dq_norm_sq, err_norm_sq)`` — exactly the
    outputs of the Pallas kernel artifact and of
    ``rust/src/quant/midtread.rs::quantize_innovation_fused`` +
    ``levels::aquila_level``.
    """
    g = g.astype(jnp.float32)
    q_prev = q_prev.astype(jnp.float32)
    v = g - q_prev
    l2sq = jnp.sum(v.astype(jnp.float64) * v.astype(jnp.float64))
    linf = jnp.max(jnp.abs(v)) if v.size else jnp.float32(0.0)
    bits = aquila_level(jnp.sqrt(l2sq), linf, v.size)
    _, dq, r = quantize(v, bits, linf)
    err = v - dq
    dq_norm_sq = jnp.sum(dq.astype(jnp.float64) * dq.astype(jnp.float64))
    err_norm_sq = jnp.sum(err.astype(jnp.float64) * err.astype(jnp.float64))
    return (
        dq,
        r.astype(jnp.float32),
        bits,
        dq_norm_sq.astype(jnp.float32),
        err_norm_sq.astype(jnp.float32),
    )


def skip_rule(dq_norm_sq, err_norm_sq, beta, alpha, model_diff_sq):
    """eq. 8: True = the device skips this round's upload."""
    return dq_norm_sq + err_norm_sq <= (beta / (alpha * alpha)) * model_diff_sq
