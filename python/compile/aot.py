"""AOT pipeline: lower the L2 model (+ L1 kernel) to HLO **text**
artifacts and write `manifest.json` for the Rust runtime.

Run once at build time (`make artifacts`); Python is never on the
request path. Interchange is HLO text, not `.serialize()`: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Usage:
    python -m compile.aot --out ../artifacts [--variants txf_tiny,txf_small]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import aquila_quant


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, *example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def export_variant(cfg: model.TxfConfig, out_dir: str) -> dict:
    """Lower grad/eval/step for one variant; returns its manifest
    entry."""
    d = model.dim(cfg)
    theta_spec = jax.ShapeDtypeStruct((d,), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

    files = {}
    for entry_name, fn, args in [
        ("grad", model.grad_entry(cfg), (theta_spec, tok_spec, tok_spec)),
        ("eval", model.eval_entry(cfg), (theta_spec, tok_spec, tok_spec)),
        ("step", model.step_entry(cfg), (theta_spec, theta_spec, tok_spec, tok_spec)),
    ]:
        fname = f"{entry_name}_{cfg.name}.hlo.txt"
        text = lower_entry(fn, *args)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[entry_name] = fname
        print(f"  {fname}: {len(text) / 1024:.0f} KiB")

    layout_json = []
    off = 0
    for name, shape in model.layout(cfg):
        n = 1
        for s in shape:
            n *= s
        layout_json.append({"name": name, "shape": list(shape), "offset": off})
        off += n
    assert off == d
    return {
        "name": cfg.name,
        "dim": d,
        "grad": files["grad"],
        "eval": files["eval"],
        "step": files["step"],
        "batch": cfg.batch,
        "seq": cfg.seq,
        "vocab": cfg.vocab,
        "layout": layout_json,
    }


def export_kernel(d: int, out_dir: str) -> dict:
    """Lower the standalone fused AQUILA quantizer at dimension `d`."""
    spec = jax.ShapeDtypeStruct((d,), jnp.float32)
    fname = f"aquila_quant_{d}.hlo.txt"
    text = lower_entry(aquila_quant.device_step, spec, spec)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text) / 1024:.0f} KiB")
    return {"name": f"aquila_quant_{d}", "dim": d, "file": fname}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="txf_tiny,txf_small",
        help="comma-separated subset of: " + ",".join(model.VARIANTS),
    )
    ap.add_argument(
        "--kernel-dims",
        default="",
        help="extra standalone quantizer dims (model dims are always exported)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": [], "kernels": []}
    kernel_dims = set()
    for vname in [v for v in args.variants.split(",") if v]:
        cfg = model.VARIANTS[vname]
        print(f"lowering variant {vname} (d = {model.dim(cfg)}):")
        manifest["models"].append(export_variant(cfg, args.out))
        kernel_dims.add(model.dim(cfg))
    for extra in [int(x) for x in args.kernel_dims.split(",") if x]:
        kernel_dims.add(extra)
    for d in sorted(kernel_dims):
        manifest["kernels"].append(export_kernel(d, args.out))

    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
