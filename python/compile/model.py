"""L2: flat-parameter transformer language model in JAX.

The neural workload of the reproduction (the paper's WikiText-2
Transformer row, per DESIGN.md §3 trained on the synthetic Markov
corpus). Parameters live in a single flat f32 vector ``theta`` whose
layout is exported to ``manifest.json`` so the Rust coordinator can
compute HeteroFL capacity masks over named tensors.

Exported entry points (all AOT-lowered to HLO text by ``aot.py``):

* ``grad``  : (theta, x, y) -> (loss, grad)
* ``eval``  : (theta, x, y) -> (loss,)
* ``step``  : (theta, q_prev, x, y)
              -> (loss, dq, range, bits, dq_norm_sq, err_norm_sq)
  — the fully fused AQUILA client computation: model fwd/bwd **and**
  the L1 Pallas quantization kernel in one HLO module, so Rust can run
  the entire device round with a single PJRT execute.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import aquila_quant


@dataclass(frozen=True)
class TxfConfig:
    """Transformer-LM hyperparameters (one `variant` = one artifact set)."""

    name: str = "txf_tiny"
    vocab: int = 64
    embed: int = 32
    layers: int = 2
    heads: int = 2
    mlp: int = 64
    seq: int = 32
    batch: int = 8

    def head_dim(self) -> int:
        assert self.embed % self.heads == 0
        return self.embed // self.heads


#: Variants available to `aot.py --variants`.
VARIANTS = {
    "txf_tiny": TxfConfig(),
    "txf_small": TxfConfig(
        name="txf_small", vocab=64, embed=128, layers=4, heads=4, mlp=512, seq=64, batch=8
    ),
    # Paper-scale config (compile-only on this CPU budget; see DESIGN.md).
    "txf_base": TxfConfig(
        name="txf_base", vocab=256, embed=512, layers=8, heads=8, mlp=2048, seq=128, batch=8
    ),
}


def layout(cfg: TxfConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Named tensors in flat order (mirrors `ParamLayout` on the Rust
    side)."""
    spec: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.embed)),
        ("pos", (cfg.seq, cfg.embed)),
    ]
    for l in range(cfg.layers):
        spec += [
            (f"l{l}.ln1_scale", (cfg.embed,)),
            (f"l{l}.ln1_bias", (cfg.embed,)),
            (f"l{l}.wq", (cfg.embed, cfg.embed)),
            (f"l{l}.wk", (cfg.embed, cfg.embed)),
            (f"l{l}.wv", (cfg.embed, cfg.embed)),
            (f"l{l}.wo", (cfg.embed, cfg.embed)),
            (f"l{l}.ln2_scale", (cfg.embed,)),
            (f"l{l}.ln2_bias", (cfg.embed,)),
            (f"l{l}.mlp_w1", (cfg.embed, cfg.mlp)),
            (f"l{l}.mlp_b1", (cfg.mlp,)),
            (f"l{l}.mlp_w2", (cfg.mlp, cfg.embed)),
            (f"l{l}.mlp_b2", (cfg.embed,)),
        ]
    spec += [
        ("lnf_scale", (cfg.embed,)),
        ("lnf_bias", (cfg.embed,)),
        ("unembed", (cfg.embed, cfg.vocab)),
    ]
    return spec


def dim(cfg: TxfConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in layout(cfg))


def unflatten(cfg: TxfConfig, theta: jnp.ndarray) -> dict[str, jnp.ndarray]:
    params = {}
    off = 0
    for name, shape in layout(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = theta[off : off + n].reshape(shape)
        off += n
    return params


def init_theta(cfg: TxfConfig, key: jax.Array) -> jnp.ndarray:
    """Scaled-gaussian init, flat."""
    chunks = []
    for name, shape in layout(cfg):
        key, sub = jax.random.split(key)
        n = 1
        for s in shape:
            n *= s
        if name.endswith(("_scale",)) or name.endswith("ln1_scale"):
            chunks.append(jnp.ones(n, jnp.float32))
        elif name.endswith(("_bias", "_b1", "_b2")):
            chunks.append(jnp.zeros(n, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else n
            std = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            chunks.append(jax.random.normal(sub, (n,), jnp.float32) * std)
    return jnp.concatenate(chunks)


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(cfg: TxfConfig, p, l: int, h: jnp.ndarray) -> jnp.ndarray:
    b, s, e = h.shape
    hd = cfg.head_dim()
    q = (h @ p[f"l{l}.wq"]).reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
    k = (h @ p[f"l{l}.wk"]).reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
    v = (h @ p[f"l{l}.wv"]).reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
    scores = q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = (attn @ v).transpose(0, 2, 1, 3).reshape(b, s, e)
    return out @ p[f"l{l}.wo"]


def forward(cfg: TxfConfig, theta: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Logits ``(B, S, V)`` for token ids ``x (B, S)``. Pre-LN GPT."""
    p = unflatten(cfg, theta)
    h = p["embed"][x] + p["pos"][None, : x.shape[1], :]
    for l in range(cfg.layers):
        a = _attention(cfg, p, l, _layer_norm(h, p[f"l{l}.ln1_scale"], p[f"l{l}.ln1_bias"]))
        h = h + a
        z = _layer_norm(h, p[f"l{l}.ln2_scale"], p[f"l{l}.ln2_bias"])
        z = jax.nn.gelu(z @ p[f"l{l}.mlp_w1"] + p[f"l{l}.mlp_b1"])
        h = h + z @ p[f"l{l}.mlp_w2"] + p[f"l{l}.mlp_b2"]
    h = _layer_norm(h, p["lnf_scale"], p["lnf_bias"])
    return h @ p["unembed"]


def loss_fn(cfg: TxfConfig, theta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """Mean next-token cross-entropy."""
    logits = forward(cfg, theta, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def grad_entry(cfg: TxfConfig):
    """(theta, x, y) -> (loss, grad) — the per-round device compute."""

    def f(theta, x, y):
        loss, grad = jax.value_and_grad(lambda t: loss_fn(cfg, t, x, y))(theta)
        return loss, grad

    return f


def eval_entry(cfg: TxfConfig):
    """(theta, x, y) -> (loss,) — held-out evaluation."""

    def f(theta, x, y):
        return (loss_fn(cfg, theta, x, y),)

    return f


def step_entry(cfg: TxfConfig):
    """The fused AQUILA device step: model grad + L1 Pallas quantizer in
    one HLO module."""

    def f(theta, q_prev, x, y):
        loss, grad = jax.value_and_grad(lambda t: loss_fn(cfg, t, x, y))(theta)
        dq, rng, bits, dq_norm_sq, err_norm_sq = aquila_quant.device_step(grad, q_prev)
        return loss, dq, rng, bits, dq_norm_sq, err_norm_sq

    return f
