"""L2 correctness: transformer shapes, gradient sanity, trainability,
and the fused device-step entry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.TxfConfig(
    name="test", vocab=16, embed=16, layers=1, heads=2, mlp=32, seq=8, batch=4
)


def _batch(key, cfg=CFG):
    kx, ky = jax.random.split(key)
    x = jax.random.randint(kx, (cfg.batch, cfg.seq), 0, cfg.vocab)
    y = jax.random.randint(ky, (cfg.batch, cfg.seq), 0, cfg.vocab)
    return x, y


def test_layout_covers_dim():
    d = model.dim(CFG)
    off = 0
    for name, shape in model.layout(CFG):
        n = int(np.prod(shape))
        off += n
    assert off == d
    theta = model.init_theta(CFG, jax.random.PRNGKey(0))
    assert theta.shape == (d,)
    params = model.unflatten(CFG, theta)
    assert params["embed"].shape == (16, 16)
    assert params["l0.mlp_w1"].shape == (16, 32)


def test_forward_shapes_and_finite():
    theta = model.init_theta(CFG, jax.random.PRNGKey(1))
    x, _ = _batch(jax.random.PRNGKey(2))
    logits = model.forward(CFG, theta, x)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    theta = model.init_theta(CFG, jax.random.PRNGKey(3))
    x, y = _batch(jax.random.PRNGKey(4))
    loss = model.loss_fn(CFG, theta, x, y)
    assert float(loss) == pytest.approx(np.log(CFG.vocab), rel=0.3)


def test_grad_matches_finite_differences():
    theta = model.init_theta(CFG, jax.random.PRNGKey(5))
    x, y = _batch(jax.random.PRNGKey(6))
    loss, grad = model.grad_entry(CFG)(theta, x, y)
    eps = 1e-2
    rng = np.random.default_rng(0)
    for i in rng.integers(0, model.dim(CFG), size=5):
        tp = theta.at[i].add(eps)
        tm = theta.at[i].add(-eps)
        fd = (model.loss_fn(CFG, tp, x, y) - model.loss_fn(CFG, tm, x, y)) / (2 * eps)
        denom = max(abs(float(fd)), abs(float(grad[i])), 1e-3)
        assert abs(float(fd) - float(grad[i])) / denom < 0.15, i


def test_causality():
    """Changing a future token must not affect earlier logits."""
    theta = model.init_theta(CFG, jax.random.PRNGKey(7))
    x, _ = _batch(jax.random.PRNGKey(8))
    l1 = model.forward(CFG, theta, x)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % CFG.vocab)
    l2 = model.forward(CFG, theta, x2)
    np.testing.assert_allclose(
        np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), rtol=1e-5, atol=1e-6
    )


def test_sgd_reduces_loss():
    theta = model.init_theta(CFG, jax.random.PRNGKey(9))
    x, y = _batch(jax.random.PRNGKey(10))
    grad_fn = jax.jit(model.grad_entry(CFG))
    loss0, _ = grad_fn(theta, x, y)
    for _ in range(20):
        _, g = grad_fn(theta, x, y)
        theta = theta - 0.5 * g
    loss1, _ = grad_fn(theta, x, y)
    assert float(loss1) < 0.7 * float(loss0)


def test_step_entry_fuses_grad_and_kernel():
    theta = model.init_theta(CFG, jax.random.PRNGKey(11))
    q_prev = jnp.zeros_like(theta)
    x, y = _batch(jax.random.PRNGKey(12))
    loss, dq, rng_, bits, dqn, en = jax.jit(model.step_entry(CFG))(theta, q_prev, x, y)
    # Cross-check against grad entry + reference quantizer.
    loss2, grad = model.grad_entry(CFG)(theta, x, y)
    assert float(loss) == pytest.approx(float(loss2), rel=1e-5)
    dq_r, r_r, b_r, dqn_r, en_r = ref.device_step(grad, q_prev)
    assert int(bits) == int(b_r)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_r), rtol=1e-4, atol=1e-7)
    assert float(dqn) == pytest.approx(float(dqn_r), rel=1e-3)


def test_variant_dims_increase():
    d_tiny = model.dim(model.VARIANTS["txf_tiny"])
    d_small = model.dim(model.VARIANTS["txf_small"])
    d_base = model.dim(model.VARIANTS["txf_base"])
    assert d_tiny < d_small < d_base
    assert d_base > 20_000_000  # paper-scale config exists
