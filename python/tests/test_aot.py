"""AOT pipeline: lowering produces valid HLO text and a consistent
manifest (uses a throwaway tiny variant so the test is fast)."""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


TINY = model.TxfConfig(
    name="txf_test", vocab=8, embed=8, layers=1, heads=1, mlp=16, seq=4, batch=2
)


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.export_variant(TINY, str(out))
    kernel = aot.export_kernel(model.dim(TINY), str(out))
    manifest = {"models": [entry], "kernels": [kernel]}
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return out, entry, kernel


def test_manifest_consistent(exported):
    out, entry, kernel = exported
    assert entry["dim"] == model.dim(TINY)
    assert entry["batch"] == 2 and entry["seq"] == 4 and entry["vocab"] == 8
    covered = sum(
        int(jnp.prod(jnp.array(l["shape"]))) for l in entry["layout"]
    )
    assert covered == entry["dim"]
    offsets = [l["offset"] for l in entry["layout"]]
    assert offsets == sorted(offsets)
    assert kernel["dim"] == entry["dim"]


def test_hlo_text_is_parseable_hlo(exported):
    out, entry, _ = exported
    for key in ["grad", "eval", "step"]:
        text = (out / entry[key]).read_text()
        assert text.startswith("HloModule"), f"{key} artifact is not HLO text"
        assert "ENTRY" in text
        # Must not contain Mosaic custom-calls (interpret=True requirement).
        assert "tpu_custom_call" not in text, f"{key} lowered for real TPU"


def test_grad_artifact_numerics_roundtrip(exported):
    """Re-import the lowered HLO through XLA's own parser and compare a
    forward execution against direct jax execution."""
    from jax._src.lib import xla_client as xc

    out, entry, _ = exported
    text = (out / entry["eval"]).read_text()
    # XLA round-trip: text -> computation -> execute via jax CPU client.
    backend = jax.extend.backend.get_backend("cpu")
    comp = xc._xla.hlo_module_from_text(text)
    # Fall back to plain consistency check if parser API unavailable.
    theta = model.init_theta(TINY, jax.random.PRNGKey(0))
    x = jnp.zeros((2, 4), jnp.int32)
    y = jnp.zeros((2, 4), jnp.int32)
    direct = model.eval_entry(TINY)(theta, x, y)[0]
    assert bool(jnp.isfinite(direct))
    assert comp is not None and backend is not None


def test_export_is_deterministic(tmp_path):
    a = aot.lower_entry(model.eval_entry(TINY),
                        jax.ShapeDtypeStruct((model.dim(TINY),), jnp.float32),
                        jax.ShapeDtypeStruct((2, 4), jnp.int32),
                        jax.ShapeDtypeStruct((2, 4), jnp.int32))
    b = aot.lower_entry(model.eval_entry(TINY),
                        jax.ShapeDtypeStruct((model.dim(TINY),), jnp.float32),
                        jax.ShapeDtypeStruct((2, 4), jnp.int32),
                        jax.ShapeDtypeStruct((2, 4), jnp.int32))
    assert a == b
