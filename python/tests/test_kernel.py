"""L1 correctness: the Pallas fused quantizer vs the pure-jnp oracle,
plus the quantizer/level-rule properties the paper's theory relies on.

Hypothesis sweeps dimensions (crossing the BLOCK=2048 tiling boundary),
value scales, and degenerate inputs.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aquila_quant as aq
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=6000)


def _vec(rng, d, scale):
    return (rng.normal(size=d) * scale).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(
    d=DIMS,
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_pallas_matches_ref(d, seed, scale):
    rng = np.random.default_rng(seed)
    g = _vec(rng, d, scale)
    q = _vec(rng, d, scale)
    dq_r, r_r, b_r, dqn_r, en_r = [np.asarray(x) for x in ref.device_step(jnp.array(g), jnp.array(q))]
    dq_p, r_p, b_p, dqn_p, en_p = [np.asarray(x) for x in aq.device_step(jnp.array(g), jnp.array(q))]
    assert b_r == b_p
    assert r_r == pytest.approx(r_p, rel=1e-6)
    np.testing.assert_allclose(dq_p, dq_r, rtol=1e-5, atol=1e-6 * scale)
    np.testing.assert_allclose(dqn_p, dqn_r, rtol=1e-3, atol=1e-9)
    np.testing.assert_allclose(en_p, en_r, rtol=2e-2, atol=1e-9 * scale * scale)


@settings(max_examples=30, deadline=None)
@given(d=DIMS, seed=st.integers(min_value=0, max_value=2**31))
def test_level_rule_bounds(d, seed):
    """Theorem 1 self-consistency: 1 <= b* <= ceil(log2(sqrt(d)+1))."""
    rng = np.random.default_rng(seed)
    v = _vec(rng, d, 1.0)
    l2 = float(np.linalg.norm(v.astype(np.float64)))
    linf = float(np.max(np.abs(v)))
    b = int(ref.aquila_level(jnp.float32(l2), jnp.float32(linf), d))
    assert 1 <= b <= max(1, math.ceil(math.log2(math.sqrt(d) + 1)))


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=3000),
    bits=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_midtread_error_bound(d, bits, seed):
    """|v_i - dq_i| <= tau * R per element (Definition 2 mid-tread)."""
    rng = np.random.default_rng(seed)
    v = jnp.array(_vec(rng, d, 2.0))
    psi, dq, r = ref.quantize(v, jnp.int32(bits))
    tau = 1.0 / (2.0**bits - 1.0)
    bound = tau * float(r) + 1e-6 * float(r)
    assert np.all(np.abs(np.asarray(v) - np.asarray(dq)) <= bound + 1e-12)
    # codes representable in `bits` bits
    assert np.all(np.asarray(psi) >= 0)
    assert np.all(np.asarray(psi) <= 2.0**bits - 1.0)


def test_zero_innovation():
    z = jnp.zeros(257, jnp.float32)
    dq, r, b, dqn, en = aq.device_step(z, z)
    assert float(r) == 0.0
    assert int(b) == 1
    assert float(dqn) == 0.0 and float(en) == 0.0
    assert np.all(np.asarray(dq) == 0.0)


def test_extreme_values_map_to_end_codes():
    v = jnp.array([5.0, -5.0, 0.0], jnp.float32)
    psi, dq, r = ref.quantize(v, jnp.int32(4))
    assert float(r) == 5.0
    np.testing.assert_allclose(np.asarray(dq)[[0, 1]], [5.0, -5.0], rtol=1e-6)
    assert int(np.asarray(psi)[0]) == 15
    assert int(np.asarray(psi)[1]) == 0


def test_skip_rule_matches_eq8():
    assert bool(ref.skip_rule(1.0, 1.0, beta=0.5, alpha=0.1, model_diff_sq=1.0))
    assert not bool(ref.skip_rule(1.0, 1.0, beta=0.5, alpha=0.1, model_diff_sq=0.01))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_block_boundary_consistency(seed):
    """d exactly at / around the Pallas BLOCK boundary must agree with
    the oracle (padding masks correct)."""
    rng = np.random.default_rng(seed)
    for d in [aq.BLOCK - 1, aq.BLOCK, aq.BLOCK + 1, 2 * aq.BLOCK]:
        g = jnp.array(_vec(rng, d, 1.0))
        q = jnp.array(_vec(rng, d, 1.0))
        out_p = aq.device_step(g, q)
        out_r = ref.device_step(g, q)
        assert int(out_p[2]) == int(out_r[2])
        np.testing.assert_allclose(np.asarray(out_p[0]), np.asarray(out_r[0]), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(out_p[4]), float(out_r[4]), rtol=2e-2, atol=1e-9)


def test_level_increases_for_spiky_innovation():
    d = 1024
    flat = jnp.ones(d, jnp.float32)
    spiky = jnp.zeros(d, jnp.float32).at[3].set(10.0)
    zero = jnp.zeros(d, jnp.float32)
    b_flat = int(aq.device_step(flat, zero)[2])
    b_spiky = int(aq.device_step(spiky, zero)[2])
    assert b_flat == 1
    assert b_spiky == math.ceil(math.log2(math.sqrt(d) + 1))
